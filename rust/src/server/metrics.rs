//! Serving metrics: TTFT / TPOT / end-to-end latency distributions and
//! throughput, aggregated across requests.
//!
//! Throughput is measured over the *wall-clock span* from the first
//! dispatch to the last completion (the server stamps both on its epoch
//! clock via [`Metrics::note_dispatch_at`] / [`Metrics::note_complete_at`]).
//! Summing per-request busy time would double-count overlapping work under
//! concurrent sessions; the per-request sum is still tracked separately as
//! `busy_ms` because `busy / span` is the node's effective parallelism.
//!
//! Latency quantiles (TTFT / e2e / TPOT p50, p99) come from streaming
//! [`LogHistogram`]s — O(1) memory per observation, ≤ ~4.5% relative
//! quantile error — so a sustained-load serve never grows an unbounded
//! sample buffer.

use super::controller::{ControllerStats, SessionGauge};
use crate::coordinator::pool::PoolStats;
use crate::coordinator::{FaultPlan, FaultStats};
use crate::runtime::kv::StoreStats;
use crate::stats::{LogHistogram, OnlineStats};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

#[derive(Debug, Default)]
pub struct Metrics {
    ttft: OnlineStats,
    wall: OnlineStats,
    queue: OnlineStats,
    ttft_hist: LogHistogram,
    wall_hist: LogHistogram,
    /// Per-request mean time-per-output-token, ms — `(wall - ttft) /
    /// (tokens - 1)`; single-token requests contribute no TPOT sample.
    tpot_hist: LogHistogram,
    tokens: u64,
    requests: u64,
    /// Sum of per-request generation walls (overlaps under concurrency).
    busy_ms: f64,
    /// Epoch-clock ms of the first dispatch, if the server stamped one.
    first_dispatch_ms: Option<f64>,
    /// Epoch-clock ms of the latest completion.
    last_complete_ms: Option<f64>,
    /// Live concurrent-generation gauge, shared with the serving loop.
    active_gauge: Option<Arc<AtomicUsize>>,
    /// Dispatch-path timing of the shared target pool, if one is serving.
    pool_stats: Option<Arc<PoolStats>>,
    /// Settled-block store counters (one handle per attached store — e.g.
    /// per engine role); snapshots sum their eviction pressure.
    store_stats: Vec<Arc<StoreStats>>,
    /// Adaptive control-plane counters and per-session gauges, if a
    /// controller is attached (idle-zero otherwise).
    controller_stats: Option<Arc<ControllerStats>>,
    /// Fault-plane counters (deadline expiries, drafter stops/restarts,
    /// degraded sessions), shared with every DSI session the server runs.
    fault_stats: Option<Arc<FaultStats>>,
    /// The injected-fault plan, if the serve runs under one — snapshots
    /// report how many of its events actually fired.
    fault_plan: Option<Arc<FaultPlan>>,
}

/// A point-in-time summary.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub tokens: u64,
    pub ttft_mean_ms: f64,
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    pub wall_mean_ms: f64,
    pub wall_p50_ms: f64,
    pub wall_p99_ms: f64,
    /// Per-request mean time-per-output-token, ms (NaN until a request
    /// with ≥ 2 output tokens completes).
    pub tpot_mean_ms: f64,
    pub tpot_p50_ms: f64,
    pub tpot_p99_ms: f64,
    pub queue_mean_ms: f64,
    /// Tokens per second over the first-dispatch..last-completion span.
    pub tokens_per_s: f64,
    /// Wall-clock serving span the throughput was computed over, ms.
    pub span_ms: f64,
    /// Generations in flight at snapshot time.
    pub active_sessions: usize,
    /// Verification tasks the shared pool's workers ran (0 without a pool).
    pub pool_tasks: u64,
    /// Mean submit→pop queue wait of pool tasks, µs — over every popped
    /// task including skipped ones, so rejection-staled tasks don't
    /// vanish from the gauge. The serving-level symptom of an
    /// oversubscribed SP budget.
    pub pool_queue_wait_us_mean: f64,
    /// Mean pop→forward dispatch overhead of pool tasks, µs. The
    /// coordination tax per task — what the zero-copy hot path minimizes.
    pub pool_dispatch_us_mean: f64,
    /// Pool tasks popped but skipped because a rejection staled their
    /// generation while they queued.
    pub pool_skipped_stale: u64,
    /// Pool tasks popped but skipped because their session had departed.
    pub pool_skipped_departed: u64,
    /// Queued pool tasks preemptively reclaimed by SP share shrinks
    /// (purged from the queue and handed back to their coordinator, never
    /// silently dropped).
    pub pool_reclaimed: u64,
    /// Fraction of pool pops that stayed on the worker's previous session
    /// (warm KV state); 0 when nothing ran.
    pub pool_affinity_hit_rate: f64,
    /// Batched forwards the pool workers executed (every dispatched task
    /// rode in exactly one).
    pub pool_batches: u64,
    /// Mean verification lanes per batched forward (0 before any ran);
    /// the batched-plane utilization gauge — 1.0 means the plane
    /// degenerated to serial.
    pub pool_batch_occupancy_mean: f64,
    /// Context positions pool forwards served from incremental KV state
    /// (retained or block-restored) instead of re-decoding.
    pub kv_tokens_reused: u64,
    /// Context positions pool forwards re-decoded.
    pub kv_tokens_redecoded: u64,
    /// Settled blocks LRU-evicted (dropped outright) across the attached
    /// block stores — with a cold tier enabled this counts only blocks
    /// the cold tier also couldn't hold.
    pub kv_blocks_evicted: u64,
    /// Settled blocks demoted hot→cold (encoded, still recoverable)
    /// instead of dropped, summed across attached stores.
    pub kv_blocks_demoted: u64,
    /// Cold blocks rehydrated back into the hot tier by the background
    /// promoter, summed across attached stores.
    pub kv_blocks_promoted: u64,
    /// Lookups that missed hot but matched a cold block (each queues an
    /// async promotion), summed across attached stores.
    pub kv_cold_hits: u64,
    /// Encoded bytes currently resident in the cold tiers.
    pub kv_cold_bytes: u64,
    /// Blocks touched by ≥ 2 distinct sessions — the cross-session
    /// prefix-dedup gauge (each shared block counted once).
    pub kv_shared_blocks: u64,
    /// Adaptive-controller ticks executed (0 when serving statically).
    pub controller_ticks: u64,
    /// Ticks whose emitted (lookahead, SP) allocation differed from the
    /// previous one — how often the live operating point actually moved.
    pub controller_replans: u64,
    /// The admission-aware batch cap the controller last applied (0
    /// before any planning tick / without a controller).
    pub batch_cap_current: usize,
    /// Live measured target per-task forward cost the controller last
    /// planned with, ms (0 until the pool plane reported).
    pub controller_target_tpot_ms: f64,
    /// Membership-change wakeups (admissions/completions) that kicked the
    /// controller out of its inter-tick sleep.
    pub controller_membership_kicks: u64,
    /// Queued verify tasks the controller preemptively reclaimed when a
    /// tick shrank a session's SP share.
    pub controller_reclaims: u64,
    /// Drafter-portfolio switches the controller requested (a challenger
    /// member beat the incumbent by the hysteresis margin).
    pub controller_drafter_switches: u64,
    /// Per-session live plans and estimates from the controller's last
    /// planning tick: (lookahead, sp_share, acceptance EWMA, measured
    /// drafter TPOT).
    pub per_session: Vec<SessionGauge>,
    /// Verify tasks the pool re-queued (at the front of their sub-queue)
    /// after a worker died mid-flight — lossless re-dispatch, never a
    /// dropped token.
    pub pool_redispatched: u64,
    /// Pool workers respawned after a panic escaped a forward.
    pub pool_worker_restarts: u64,
    /// Verify deadlines that expired: a session went silent past its
    /// deadline with results still in flight and re-dispatched the
    /// uncovered spans.
    pub deadline_expiries: u64,
    /// Sessions that exhausted their drafter-restart budget and degraded
    /// to target-only (non-SI) pace. Still lossless — just slower.
    pub degraded_sessions: u64,
    /// DrafterStopped events sessions observed (a stop precedes either a
    /// restart or a degradation).
    pub drafter_stops: u64,
    /// Supervised drafter restarts that were attempted.
    pub drafter_restarts: u64,
    /// Fault-plan events that actually fired (0 without a plan).
    pub faults_injected: u64,
    /// Whether an injected-fault plan is attached at all. A chaos run
    /// whose schedule never fired renders its fault segment anyway —
    /// explicit zeros are evidence the plan was armed, absence of the
    /// segment is evidence no plan existed.
    pub fault_plan_attached: bool,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Share the live concurrent-generation gauge (owned by the server's
    /// scheduling loop) so snapshots can report it.
    pub fn attach_active_gauge(&mut self, gauge: Arc<AtomicUsize>) {
        self.active_gauge = Some(gauge);
    }

    /// Share the target pool's dispatch-path counters so snapshots expose
    /// queue wait and dispatch overhead.
    pub fn attach_pool_stats(&mut self, stats: Arc<PoolStats>) {
        self.pool_stats = Some(stats);
    }

    /// Share a settled-block store's counters; snapshots sum eviction
    /// pressure over every attached store.
    pub fn attach_store_stats(&mut self, stats: Arc<StoreStats>) {
        self.store_stats.push(stats);
    }

    /// Share the adaptive controller's counters and per-session gauges.
    pub fn attach_controller_stats(&mut self, stats: Arc<ControllerStats>) {
        self.controller_stats = Some(stats);
    }

    /// Share the fault-plane counters (deadline expiries, drafter
    /// stops/restarts, degraded sessions) so snapshots expose them.
    pub fn attach_fault_stats(&mut self, stats: Arc<FaultStats>) {
        self.fault_stats = Some(stats);
    }

    /// Share the injected-fault plan so snapshots report how many of its
    /// events fired.
    pub fn attach_fault_plan(&mut self, plan: Arc<FaultPlan>) {
        self.fault_plan = Some(plan);
    }

    /// Record that a request was dispatched at `now_ms` on the server's
    /// epoch clock. Only the earliest stamp is kept.
    pub fn note_dispatch_at(&mut self, now_ms: f64) {
        if self.first_dispatch_ms.map_or(true, |t| now_ms < t) {
            self.first_dispatch_ms = Some(now_ms);
        }
    }

    /// Record that a request completed at `now_ms` on the server's epoch
    /// clock. Only the latest stamp is kept.
    pub fn note_complete_at(&mut self, now_ms: f64) {
        if self.last_complete_ms.map_or(true, |t| now_ms > t) {
            self.last_complete_ms = Some(now_ms);
        }
    }

    pub fn observe(&mut self, resp: &super::Response) {
        self.ttft.push(resp.ttft_ms);
        self.wall.push(resp.wall_ms);
        self.queue.push(resp.queue_ms);
        self.ttft_hist.push(resp.ttft_ms);
        self.wall_hist.push(resp.wall_ms);
        if resp.tokens.len() > 1 {
            self.tpot_hist
                .push((resp.wall_ms - resp.ttft_ms).max(0.0) / (resp.tokens.len() - 1) as f64);
        }
        self.tokens += resp.tokens.len() as u64;
        self.requests += 1;
        self.busy_ms += resp.wall_ms;
    }

    /// The throughput span: dispatch..completion if the server stamped
    /// both, otherwise the summed busy time (sequential fallback — the
    /// two coincide when nothing overlaps).
    fn span_ms(&self) -> f64 {
        match (self.first_dispatch_ms, self.last_complete_ms) {
            (Some(a), Some(b)) if b > a => b - a,
            _ => self.busy_ms,
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let span_ms = self.span_ms();
        Snapshot {
            requests: self.requests,
            tokens: self.tokens,
            ttft_mean_ms: self.ttft.mean(),
            ttft_p50_ms: self.ttft_hist.p50(),
            ttft_p99_ms: self.ttft_hist.p99(),
            wall_mean_ms: self.wall.mean(),
            wall_p50_ms: self.wall_hist.p50(),
            wall_p99_ms: self.wall_hist.p99(),
            tpot_mean_ms: self.tpot_hist.mean(),
            tpot_p50_ms: self.tpot_hist.p50(),
            tpot_p99_ms: self.tpot_hist.p99(),
            queue_mean_ms: self.queue.mean(),
            tokens_per_s: if span_ms > 0.0 {
                self.tokens as f64 / (span_ms / 1e3)
            } else {
                f64::NAN
            },
            span_ms,
            active_sessions: self
                .active_gauge
                .as_ref()
                .map_or(0, |g| g.load(Ordering::Acquire)),
            pool_tasks: self.pool_stats.as_ref().map_or(0, |s| s.tasks()),
            pool_queue_wait_us_mean: self
                .pool_stats
                .as_ref()
                .map_or(0.0, |s| s.queue_wait_us_mean()),
            pool_dispatch_us_mean: self
                .pool_stats
                .as_ref()
                .map_or(0.0, |s| s.dispatch_us_mean()),
            pool_skipped_stale: self.pool_stats.as_ref().map_or(0, |s| s.skipped_stale()),
            pool_skipped_departed: self
                .pool_stats
                .as_ref()
                .map_or(0, |s| s.skipped_departed()),
            pool_reclaimed: self.pool_stats.as_ref().map_or(0, |s| s.reclaimed()),
            pool_affinity_hit_rate: self
                .pool_stats
                .as_ref()
                .map_or(0.0, |s| s.affinity_hit_rate()),
            pool_batches: self.pool_stats.as_ref().map_or(0, |s| s.batches()),
            pool_batch_occupancy_mean: self
                .pool_stats
                .as_ref()
                .map_or(0.0, |s| s.batch_occupancy_mean()),
            kv_tokens_reused: self.pool_stats.as_ref().map_or(0, |s| s.kv_tokens_reused()),
            kv_tokens_redecoded: self
                .pool_stats
                .as_ref()
                .map_or(0, |s| s.kv_tokens_redecoded()),
            kv_blocks_evicted: self.store_stats.iter().map(|s| s.evicted()).sum(),
            kv_blocks_demoted: self.store_stats.iter().map(|s| s.demoted()).sum(),
            kv_blocks_promoted: self.store_stats.iter().map(|s| s.promoted()).sum(),
            kv_cold_hits: self.store_stats.iter().map(|s| s.cold_hits()).sum(),
            kv_cold_bytes: self.store_stats.iter().map(|s| s.cold_bytes()).sum(),
            kv_shared_blocks: self.store_stats.iter().map(|s| s.shared_blocks()).sum(),
            controller_ticks: self.controller_stats.as_ref().map_or(0, |s| s.ticks()),
            controller_replans: self.controller_stats.as_ref().map_or(0, |s| s.replans()),
            batch_cap_current: self
                .controller_stats
                .as_ref()
                .map_or(0, |s| s.batch_cap_current()),
            controller_target_tpot_ms: self
                .controller_stats
                .as_ref()
                .map_or(0.0, |s| s.target_tpot_ms()),
            controller_membership_kicks: self
                .controller_stats
                .as_ref()
                .map_or(0, |s| s.membership_kicks()),
            controller_reclaims: self
                .controller_stats
                .as_ref()
                .map_or(0, |s| s.reclaims()),
            controller_drafter_switches: self
                .controller_stats
                .as_ref()
                .map_or(0, |s| s.drafter_switches()),
            per_session: self
                .controller_stats
                .as_ref()
                .map_or_else(Vec::new, |s| s.session_gauges()),
            pool_redispatched: self.pool_stats.as_ref().map_or(0, |s| s.redispatched()),
            pool_worker_restarts: self
                .pool_stats
                .as_ref()
                .map_or(0, |s| s.worker_restarts()),
            deadline_expiries: self
                .fault_stats
                .as_ref()
                .map_or(0, |s| s.deadline_expiries()),
            degraded_sessions: self
                .fault_stats
                .as_ref()
                .map_or(0, |s| s.degraded_sessions()),
            drafter_stops: self.fault_stats.as_ref().map_or(0, |s| s.drafter_stops()),
            drafter_restarts: self
                .fault_stats
                .as_ref()
                .map_or(0, |s| s.drafter_restarts()),
            faults_injected: self.fault_plan.as_ref().map_or(0, |p| p.injected()),
            fault_plan_attached: self.fault_plan.is_some(),
        }
    }
}

impl Snapshot {
    /// Render as aligned text for logs and the e2e example.
    pub fn render(&self) -> String {
        let mut out = format!(
            "requests={} tokens={} active={} | ttft mean={:.2}ms p50={:.2} p99={:.2} | \
             e2e mean={:.2}ms p50={:.2} p99={:.2} | tpot mean={:.3}ms p50={:.3} p99={:.3} | \
             queue mean={:.2}ms | \
             {:.1} tok/s over {:.0}ms | pool tasks={} wait={:.0}µs dispatch={:.1}µs \
             skipped stale={} departed={} reclaimed={} | affinity={:.0}% | \
             batches={} occupancy={:.2} | kv reused={} redecoded={} evicted={}",
            self.requests,
            self.tokens,
            self.active_sessions,
            self.ttft_mean_ms,
            self.ttft_p50_ms,
            self.ttft_p99_ms,
            self.wall_mean_ms,
            self.wall_p50_ms,
            self.wall_p99_ms,
            self.tpot_mean_ms,
            self.tpot_p50_ms,
            self.tpot_p99_ms,
            self.queue_mean_ms,
            self.tokens_per_s,
            self.span_ms,
            self.pool_tasks,
            self.pool_queue_wait_us_mean,
            self.pool_dispatch_us_mean,
            self.pool_skipped_stale,
            self.pool_skipped_departed,
            self.pool_reclaimed,
            self.pool_affinity_hit_rate * 100.0,
            self.pool_batches,
            self.pool_batch_occupancy_mean,
            self.kv_tokens_reused,
            self.kv_tokens_redecoded,
            self.kv_blocks_evicted,
        );
        // Cold-tier segment only when a tiered store actually did tiered
        // work (or is holding cold bytes) — a single-tier serve's render
        // stays byte-identical to the pre-tiering output.
        if self.kv_blocks_demoted > 0
            || self.kv_blocks_promoted > 0
            || self.kv_cold_hits > 0
            || self.kv_cold_bytes > 0
            || self.kv_shared_blocks > 0
        {
            out.push_str(&format!(
                " | kv cold demoted={} promoted={} hits={} bytes={} shared={}",
                self.kv_blocks_demoted,
                self.kv_blocks_promoted,
                self.kv_cold_hits,
                self.kv_cold_bytes,
                self.kv_shared_blocks,
            ));
        }
        if self.controller_ticks > 0 {
            out.push_str(&format!(
                " | ctl ticks={} replans={} cap={} target={:.2}ms kicks={} reclaims={} switches={}",
                self.controller_ticks,
                self.controller_replans,
                self.batch_cap_current,
                self.controller_target_tpot_ms,
                self.controller_membership_kicks,
                self.controller_reclaims,
                self.controller_drafter_switches,
            ));
        }
        // Fault-plane segment whenever a fault plan is armed (explicit
        // zeros prove the schedule simply never fired) or anything
        // actually happened; a healthy plan-free serve stays visually
        // identical to the pre-fault-plane output.
        if self.fault_plan_attached
            || self.pool_worker_restarts > 0
            || self.pool_redispatched > 0
            || self.deadline_expiries > 0
            || self.drafter_stops > 0
            || self.faults_injected > 0
        {
            out.push_str(&format!(
                " | faults injected={} restarts={} redispatched={} expiries={} \
                 drafter stops={} restarts={} degraded={}",
                self.faults_injected,
                self.pool_worker_restarts,
                self.pool_redispatched,
                self.deadline_expiries,
                self.drafter_stops,
                self.drafter_restarts,
                self.degraded_sessions,
            ));
        }
        for g in &self.per_session {
            out.push_str(&format!(
                "\n    session {}: k={} sp={} acc={:.2} drafter={:.2}ms w={:.1} member={}",
                g.session,
                g.lookahead,
                g.sp_share,
                g.acceptance_ewma,
                g.drafter_tpot_ms,
                g.weight,
                g.drafter_member,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgoKind;

    fn resp(ttft: f64, wall: f64, n: usize) -> crate::server::Response {
        crate::server::Response {
            id: 0,
            tokens: vec![0; n],
            text: String::new(),
            ttft_ms: ttft,
            wall_ms: wall,
            queue_ms: 1.0,
            algo: AlgoKind::Dsi,
            lookahead: 2,
            sp_degree: 4,
            tenant: 0,
            weight: 1.0,
            slo: crate::workload::SloClass::Standard,
        }
    }

    #[test]
    fn aggregates_sequential_fallback() {
        // No dispatch/complete stamps: throughput falls back to summed
        // busy time, matching the sequential-serving interpretation.
        let mut m = Metrics::new();
        m.observe(&resp(10.0, 100.0, 20));
        m.observe(&resp(20.0, 200.0, 30));
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.tokens, 50);
        assert!((s.ttft_mean_ms - 15.0).abs() < 1e-9);
        assert!((s.wall_mean_ms - 150.0).abs() < 1e-9);
        // 50 tokens over 300ms busy
        assert!((s.tokens_per_s - 50.0 / 0.3).abs() < 1e-6);
        assert_eq!(s.active_sessions, 0);
        assert!(!s.render().is_empty());
    }

    #[test]
    fn throughput_uses_wall_span_not_busy_sum() {
        // Two fully overlapping 100ms requests dispatched at t=0 and
        // finishing at t=100: 40 tokens over 100ms of wall, not 200ms of
        // summed busy time.
        let mut m = Metrics::new();
        m.note_dispatch_at(0.0);
        m.note_dispatch_at(1.0); // later dispatch must not shrink the span
        m.observe(&resp(10.0, 100.0, 20));
        m.note_complete_at(99.0);
        m.observe(&resp(10.0, 100.0, 20));
        m.note_complete_at(100.0);
        let s = m.snapshot();
        assert!((s.span_ms - 100.0).abs() < 1e-9);
        assert!((s.tokens_per_s - 40.0 / 0.1).abs() < 1e-6);
    }

    #[test]
    fn pool_gauges_are_reported() {
        let mut m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.pool_tasks, 0);
        assert_eq!(s.pool_queue_wait_us_mean, 0.0);
        assert_eq!(s.pool_dispatch_us_mean, 0.0);
        assert_eq!(s.pool_skipped_stale, 0);
        assert_eq!(s.pool_skipped_departed, 0);
        assert_eq!(s.pool_affinity_hit_rate, 0.0);
        assert_eq!(s.kv_tokens_reused, 0);
        assert_eq!(s.kv_tokens_redecoded, 0);

        let stats = Arc::new(PoolStats::default());
        m.attach_pool_stats(stats.clone());
        stats.record(10_000, 2_000); // 10µs wait, 2µs dispatch
        stats.record(30_000, 4_000);
        let s = m.snapshot();
        assert_eq!(s.pool_tasks, 2);
        assert!((s.pool_queue_wait_us_mean - 20.0).abs() < 1e-9);
        assert!((s.pool_dispatch_us_mean - 3.0).abs() < 1e-9);
        assert!(s.render().contains("pool tasks=2"));
    }

    #[test]
    fn skipped_affinity_and_kv_gauges_are_reported() {
        use crate::coordinator::KvReuse;
        let mut m = Metrics::new();
        let stats = Arc::new(PoolStats::default());
        m.attach_pool_stats(stats.clone());

        stats.record(10_000, 2_000);
        // Skipped tasks carry their wait into the (un-survivor-biased)
        // mean: (10µs + 50µs) over 2 popped tasks.
        stats.record_skipped(false, 50_000);
        stats.record_skipped(true, 0);
        stats.record_affinity(true);
        stats.record_affinity(true);
        stats.record_affinity(false);
        stats.record_kv(KvReuse { tokens_reused: 128, tokens_redecoded: 32 });

        let s = m.snapshot();
        assert_eq!(s.pool_skipped_stale, 1);
        assert_eq!(s.pool_skipped_departed, 1);
        assert!((s.pool_queue_wait_us_mean - 60.0 / 3.0).abs() < 1e-9);
        assert!((s.pool_affinity_hit_rate - 2.0 / 3.0).abs() < 1e-9);
        assert_eq!(s.kv_tokens_reused, 128);
        assert_eq!(s.kv_tokens_redecoded, 32);
        let text = s.render();
        assert!(text.contains("skipped stale=1 departed=1"), "render: {text}");
        assert!(text.contains("affinity=67%"), "render: {text}");
        assert!(text.contains("kv reused=128 redecoded=32"), "render: {text}");
    }

    /// The batched-plane and store-pressure gauges: lanes-per-forward
    /// occupancy from the pool counters, summed evictions from every
    /// attached block store.
    #[test]
    fn batch_occupancy_and_eviction_gauges_are_reported() {
        use crate::runtime::kv::{key_of, BlockStore, KvBlock};
        let mut m = Metrics::new();
        let stats = Arc::new(PoolStats::default());
        m.attach_pool_stats(stats.clone());
        // 3 dispatched lanes over 2 batched forwards → occupancy 1.5.
        stats.record(0, 0);
        stats.record(0, 0);
        stats.record(0, 0);
        stats.record_batch();
        stats.record_batch();

        // A capacity-1 store: the second publish evicts the first block.
        let store: BlockStore<Vec<u32>> = BlockStore::new(2, 1);
        m.attach_store_stats(store.stats_handle());
        let block = |t: &[u32]| KvBlock { start: 0, tokens: t.to_vec(), payload: t.to_vec() };
        store.publish(key_of([1, 2]), block(&[1, 2]));
        store.publish(key_of([3, 4]), block(&[3, 4]));

        let s = m.snapshot();
        assert_eq!(s.pool_batches, 2);
        assert!((s.pool_batch_occupancy_mean - 1.5).abs() < 1e-9);
        assert_eq!(s.kv_blocks_evicted, 1);
        let text = s.render();
        assert!(text.contains("batches=2 occupancy=1.50"), "render: {text}");
        assert!(text.contains("evicted=1"), "render: {text}");
    }

    /// The cold-tier gauges: demotions, promotions, cold hits, resident
    /// cold bytes, and the cross-session dedup share flow from a tiered
    /// store into the snapshot and a render segment that single-tier
    /// serves never emit.
    #[test]
    fn cold_tier_gauges_are_reported() {
        use crate::runtime::kv::{key_of, BlockStore, KvBlock};
        let mut m = Metrics::new();
        assert!(
            !m.snapshot().render().contains("kv cold"),
            "single-tier render grew a cold segment"
        );

        // Capacity-1 hot tier over a roomy cold tier: the second publish
        // demotes the first block instead of evicting it.
        let store: BlockStore<Vec<u32>> = BlockStore::with_cold_bytes(2, 1, 1 << 16);
        m.attach_store_stats(store.stats_handle());
        let block = |t: &[u32]| KvBlock { start: 0, tokens: t.to_vec(), payload: t.to_vec() };
        store.publish(key_of([1, 2]), block(&[1, 2]));
        store.publish(key_of([3, 4]), block(&[3, 4]));
        // Cold hit on the demoted block, then rehydrate deterministically:
        // promote_now drains the queue AND barriers on the promoter's
        // in-flight key, so on return the promote-swap (promoted bump +
        // demotion of the displaced block) has fully landed — no polling.
        assert!(store.lookup(key_of([1, 2]), 0, &[1, 2]).is_none());
        store.promote_now();

        let s = m.snapshot();
        assert_eq!(s.kv_blocks_evicted, 0, "demotion must not count as eviction");
        assert_eq!(s.kv_blocks_demoted, 2, "demote on publish + demote on promote-swap");
        assert_eq!(s.kv_blocks_promoted, 1);
        assert_eq!(s.kv_cold_hits, 1);
        assert!(s.kv_cold_bytes > 0);
        let text = s.render();
        assert!(text.contains("kv cold demoted=2 promoted=1 hits=1"), "render: {text}");
    }

    /// The per-session observability surface: attached controller stats
    /// surface (lookahead, sp_share, acceptance_ewma, measured TPOT) per
    /// session plus the controller counters, both in the snapshot fields
    /// and the rendered text; without a controller everything idles at
    /// zero/empty.
    #[test]
    fn controller_and_per_session_gauges_are_reported() {
        let mut m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.controller_ticks, 0);
        assert_eq!(s.controller_replans, 0);
        assert_eq!(s.batch_cap_current, 0);
        assert!(s.per_session.is_empty());
        assert!(!s.render().contains("ctl ticks"), "idle render shows a controller");

        let stats = Arc::new(ControllerStats::default());
        m.attach_controller_stats(stats.clone());
        stats.record_plan(true, 4, 2.75);
        stats.set_session_gauges(vec![
            SessionGauge {
                session: 3,
                lookahead: 4,
                sp_share: 2,
                acceptance_ewma: 0.21,
                drafter_tpot_ms: 1.02,
                weight: 1.0,
                drafter_member: 0,
            },
            SessionGauge {
                session: 5,
                lookahead: 2,
                sp_share: 1,
                acceptance_ewma: 0.9,
                drafter_tpot_ms: 0.4,
                weight: 2.0,
                drafter_member: 1,
            },
        ]);
        // Two ticks, one of which re-planned.
        for _ in 0..2 {
            stats.record_tick();
        }
        let s = m.snapshot();
        assert_eq!(s.controller_ticks, 2);
        assert_eq!(s.controller_replans, 1);
        assert_eq!(s.batch_cap_current, 4);
        assert!((s.controller_target_tpot_ms - 2.75).abs() < 1e-3);
        assert_eq!(s.per_session.len(), 2);
        assert_eq!(
            (s.per_session[0].lookahead, s.per_session[0].sp_share),
            (4, 2)
        );
        let text = s.render();
        assert!(text.contains("ctl ticks=2 replans=1 cap=4"), "render: {text}");
        assert!(
            text.contains("session 3: k=4 sp=2 acc=0.21 drafter=1.02ms"),
            "render: {text}"
        );
        assert!(text.contains("w=2.0 member=1"), "render: {text}");
    }

    /// TPOT quantiles from the streaming histogram: per-request mean
    /// time-per-output-token, within the log-bucket error bound, with
    /// single-token requests contributing no sample.
    #[test]
    fn tpot_quantiles_are_reported() {
        let mut m = Metrics::new();
        assert!(m.snapshot().tpot_mean_ms.is_nan(), "empty TPOT must be NaN");
        // 11 tokens, 10ms ttft, 110ms wall → (110-10)/10 = 10ms/token.
        m.observe(&resp(10.0, 110.0, 11));
        // 21 tokens, 20ms ttft, 60ms wall → 2ms/token.
        m.observe(&resp(20.0, 60.0, 21));
        // A single-token request has no inter-token gaps: no TPOT sample.
        m.observe(&resp(5.0, 5.0, 1));
        let s = m.snapshot();
        assert!((s.tpot_mean_ms - 6.0).abs() < 1e-9, "exact mean, got {}", s.tpot_mean_ms);
        // Histogram quantiles land within the ~9% bucket width.
        assert!((s.tpot_p50_ms - 2.0).abs() / 2.0 < 0.1, "p50 {}", s.tpot_p50_ms);
        assert!((s.tpot_p99_ms - 10.0).abs() / 10.0 < 0.1, "p99 {}", s.tpot_p99_ms);
        // TTFT quantiles ride the same histogram machinery.
        assert!((s.ttft_p99_ms - 20.0).abs() / 20.0 < 0.1, "ttft p99 {}", s.ttft_p99_ms);
        assert!(s.render().contains("tpot mean=6.000ms"), "render: {}", s.render());
    }

    /// The preemptive-reclaim and membership-kick gauges flow from pool
    /// and controller stats into the snapshot and the rendered text.
    #[test]
    fn reclaim_and_kick_gauges_are_reported() {
        let mut m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.pool_reclaimed, s.controller_membership_kicks, s.controller_reclaims), (0, 0, 0));
        assert_eq!(s.controller_drafter_switches, 0);

        let pool = Arc::new(PoolStats::default());
        m.attach_pool_stats(pool.clone());
        pool.record_reclaimed(5_000);
        pool.record_reclaimed(15_000);
        let ctl = Arc::new(ControllerStats::default());
        m.attach_controller_stats(ctl.clone());
        ctl.record_tick();
        ctl.record_membership_kick();
        ctl.record_reclaims(2);
        ctl.record_drafter_switch();

        let s = m.snapshot();
        assert_eq!(s.pool_reclaimed, 2);
        assert_eq!(s.controller_membership_kicks, 1);
        assert_eq!(s.controller_reclaims, 2);
        assert_eq!(s.controller_drafter_switches, 1);
        // Reclaimed tasks keep their queue wait in the unbiased mean:
        // (5µs + 15µs) over 2 accounted tasks.
        assert!((s.pool_queue_wait_us_mean - 10.0).abs() < 1e-9);
        let text = s.render();
        assert!(text.contains("reclaimed=2"), "render: {text}");
        assert!(text.contains("kicks=1 reclaims=2 switches=1"), "render: {text}");
    }

    /// The fault-plane observability surface: pool supervision counters,
    /// session fault stats, and fired plan events all flow into the
    /// snapshot; the rendered segment only appears once something fired,
    /// so a healthy serve's render is unchanged.
    #[test]
    fn fault_gauges_are_reported() {
        use crate::coordinator::FaultAction;
        let mut m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(
            (s.pool_redispatched, s.pool_worker_restarts, s.deadline_expiries),
            (0, 0, 0)
        );
        assert_eq!(
            (s.degraded_sessions, s.drafter_stops, s.drafter_restarts, s.faults_injected),
            (0, 0, 0, 0)
        );
        assert!(!s.render().contains("faults"), "healthy render shows a fault segment");

        let pool = Arc::new(PoolStats::default());
        m.attach_pool_stats(pool.clone());
        pool.record_redispatched(2);
        pool.record_worker_restart();

        let fs = Arc::new(FaultStats::default());
        m.attach_fault_stats(fs.clone());
        fs.record_deadline_expiry();
        fs.record_drafter_stop();
        fs.record_drafter_stop();
        fs.record_drafter_restart();
        fs.record_degraded_session();

        let plan = Arc::new(FaultPlan::parse("worker-panic@1").unwrap());
        m.attach_fault_plan(plan.clone());
        assert_eq!(plan.on_target_forward(), FaultAction::Panic);

        let s = m.snapshot();
        assert_eq!(s.pool_redispatched, 2);
        assert_eq!(s.pool_worker_restarts, 1);
        assert_eq!(s.deadline_expiries, 1);
        assert_eq!(s.degraded_sessions, 1);
        assert_eq!(s.drafter_stops, 2);
        assert_eq!(s.drafter_restarts, 1);
        assert_eq!(s.faults_injected, 1);
        let text = s.render();
        assert!(
            text.contains("faults injected=1 restarts=1 redispatched=2 expiries=1"),
            "render: {text}"
        );
        assert!(
            text.contains("drafter stops=2 restarts=1 degraded=1"),
            "render: {text}"
        );
    }

    /// An armed-but-never-firing plan still renders the fault segment —
    /// with explicit zeros — so operators can tell "armed and quiet"
    /// apart from "no plan at all".
    #[test]
    fn armed_fault_plan_renders_explicit_zeros() {
        let mut m = Metrics::new();
        // An envelope index no short run reaches: the plan never fires.
        let plan = Arc::new(FaultPlan::parse("node-kill@999").unwrap());
        m.attach_fault_plan(plan);
        let s = m.snapshot();
        assert!(s.fault_plan_attached);
        assert_eq!(s.faults_injected, 0);
        let text = s.render();
        assert!(
            text.contains("faults injected=0 restarts=0 redispatched=0 expiries=0"),
            "render: {text}"
        );
    }

    #[test]
    fn active_gauge_is_reported() {
        let mut m = Metrics::new();
        let gauge = Arc::new(AtomicUsize::new(0));
        m.attach_active_gauge(gauge.clone());
        assert_eq!(m.snapshot().active_sessions, 0);
        gauge.store(3, Ordering::Release);
        assert_eq!(m.snapshot().active_sessions, 3);
    }
}
