//! Operating-point router: turns calibrated latencies and the live
//! acceptance-rate estimate into (lookahead, SP degree) per request.
//!
//! Policy (§3.1/§4): given the GPU budget, reserve one server for the
//! drafter, cap SP at the useful maximum `ceil(t_target/t_drafter)`, and
//! pick the minimal lookahead satisfying Equation 1 — the paper's optimal
//! choice, detecting rejections as early as the hardware allows.

use crate::config::{max_useful_sp, min_lookahead_for_sp, AlgoKind, LatencyProfile};

#[derive(Debug, Clone, Copy)]
pub struct Plan {
    pub lookahead: usize,
    pub sp_degree: usize,
}

#[derive(Debug, Clone)]
pub struct Router {
    pub target: LatencyProfile,
    pub drafter: LatencyProfile,
    /// GPU budget for target servers (node size minus drafter).
    pub sp_budget: usize,
    /// Streaming acceptance estimate (§F.2 geometric fit, online).
    accepted: u64,
    rejected: u64,
}

impl Router {
    pub fn new(target: LatencyProfile, drafter: LatencyProfile, sp_budget: usize) -> Self {
        assert!(sp_budget >= 1);
        Self { target, drafter, sp_budget, accepted: 0, rejected: 0 }
    }

    /// Live acceptance-rate estimate; NaN until observations arrive.
    pub fn acceptance_estimate(&self) -> f64 {
        let n = self.accepted + self.rejected;
        if n == 0 {
            return f64::NAN;
        }
        // mean accepted-run length = accepted/rejected; geometric fit.
        let mean_run = self.accepted as f64 / self.rejected.max(1) as f64;
        1.0 - 1.0 / (1.0 + mean_run)
    }

    /// Record a finished generation's verification outcomes.
    pub fn observe_run(&mut self, accepted: usize, rejections: usize) {
        self.accepted += accepted as u64;
        self.rejected += rejections as u64;
    }

    /// The operating point for an algorithm with the whole node to itself.
    pub fn plan(&self, algo: AlgoKind) -> Plan {
        self.plan_shared(algo, 1)
    }

    /// The operating point when `active_sessions` generations share the
    /// node: the SP budget is split evenly and Equation 1 is re-solved at
    /// the per-session share, so the lookahead/SP operating point adapts
    /// as sessions join and leave. A smaller share forces a larger
    /// lookahead (fewer, longer verification tasks per session) — the
    /// resource-vs-latency tradeoff of §3.1 at serving scale.
    pub fn plan_shared(&self, algo: AlgoKind, active_sessions: usize) -> Plan {
        let share = (self.sp_budget / active_sessions.max(1)).max(1);
        match algo {
            AlgoKind::NonSi => Plan { lookahead: 1, sp_degree: 1 },
            AlgoKind::Si | AlgoKind::Pearl => Plan {
                // SI uses a single target server; lookahead 5 is the
                // standard practice the paper cites (and sweeps around).
                lookahead: 5,
                sp_degree: 1,
            },
            AlgoKind::Dsi => {
                // Don't allocate more target servers than can ever be
                // concurrently busy (§3.1).
                let sp = share.min(max_useful_sp(self.target.tpot_ms, self.drafter.tpot_ms));
                let k = min_lookahead_for_sp(self.target.tpot_ms, self.drafter.tpot_ms, sp);
                Plan { lookahead: k, sp_degree: sp }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dsi_plan_satisfies_eq1() {
        let r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 7);
        let p = r.plan(AlgoKind::Dsi);
        assert!(crate::config::required_sp(30.0, 3.0, p.lookahead) <= p.sp_degree);
        assert!(p.sp_degree <= 7);
    }

    #[test]
    fn dsi_plan_caps_at_useful_sp() {
        // Slow drafter (50%): only 2 target servers can ever be busy.
        let r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(15.0), 7);
        let p = r.plan(AlgoKind::Dsi);
        assert_eq!(p.sp_degree, 2);
        assert_eq!(p.lookahead, 1);
    }

    #[test]
    fn acceptance_estimator_converges() {
        let mut r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 7);
        assert!(r.acceptance_estimate().is_nan());
        // p=0.8 -> mean run 4 accepted per rejection
        r.observe_run(4000, 1000);
        assert!((r.acceptance_estimate() - 0.8).abs() < 0.01);
    }

    #[test]
    fn nonsi_plan_trivial() {
        let r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 7);
        let p = r.plan(AlgoKind::NonSi);
        assert_eq!((p.lookahead, p.sp_degree), (1, 1));
    }

    #[test]
    fn shared_plan_splits_budget_and_grows_lookahead() {
        let r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 8);
        let solo = r.plan_shared(AlgoKind::Dsi, 1);
        let quad = r.plan_shared(AlgoKind::Dsi, 4);
        assert!(quad.sp_degree <= solo.sp_degree);
        assert!(quad.sp_degree <= 2, "8-way budget split 4 ways");
        // Each per-session plan still satisfies Equation 1 at its share.
        assert!(crate::config::required_sp(30.0, 3.0, quad.lookahead) <= quad.sp_degree);
        // Fewer servers per session => at least as much lookahead.
        assert!(quad.lookahead >= solo.lookahead);
    }

    #[test]
    fn shared_plan_never_starves_a_session() {
        // More sessions than budget: everyone still gets one server.
        let r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 4);
        let p = r.plan_shared(AlgoKind::Dsi, 9);
        assert_eq!(p.sp_degree, 1);
        assert!(p.lookahead >= 1);
    }
}
