//! Operating-point router: turns calibrated latencies and live acceptance
//! and latency estimates into (lookahead, SP degree) per session.
//!
//! Policy (§3.1/§4): given the GPU budget, reserve one server for the
//! drafter, cap SP at the useful maximum `ceil(t_target/t_drafter)`, and
//! pick the minimal lookahead satisfying Equation 1 — the paper's optimal
//! choice, detecting rejections as early as the hardware allows.
//!
//! Since the adaptive control plane, the router carries two strata of
//! evidence:
//!
//! - **Calibrated profiles** (boot-time `LatencyProfile`s) plus one global
//!   accepted/rejected counter — the static planner's inputs, unchanged,
//!   and the fallback whenever live evidence is cold.
//! - **Live estimators**: a per-session EWMA of the acceptance rate and of
//!   the measured drafter step cost (fed from each session's telemetry),
//!   and a global EWMA of the measured target per-task forward cost (fed
//!   from the pool's dispatch plane). The `live_*` accessors resolve these
//!   against the calibrated fallbacks, so Equation-1 replanning always has
//!   a usable operating point — warm sessions get their measured rates,
//!   cold ones the calibration.

use crate::config::{
    max_useful_sp, max_useful_sp_marginal, min_lookahead_for_sp, min_lookahead_for_sp_marginal,
    AlgoKind, LatencyProfile,
};
use crate::stats::Ewma;
use std::collections::HashMap;

/// Newest-observation weight of the live estimators. Observations arrive
/// once per control tick (not per token), so a fairly heavy alpha tracks
/// genuine drift in a handful of ticks without chasing single-tick noise.
const EWMA_ALPHA: f64 = 0.2;

/// Observations before a live estimator outranks its calibrated fallback.
const WARM_OBS: u64 = 2;

/// Acceptance prior when neither the session nor the global counter has
/// evidence yet: neutral-pessimistic, so an unknown session neither grabs
/// extra servers nor starves while its first observations arrive.
const ACCEPTANCE_PRIOR: f64 = 0.5;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Plan {
    pub lookahead: usize,
    pub sp_degree: usize,
}

/// Online least-squares fit of the drafter *block* cost model
/// `c(k) = d_base + k·d_marginal` (ms per `draft_batch` call of mean
/// width k). Under parallel drafting the per-token draft cost stops
/// being `k·d`: one forward proposes the whole window and extra tokens
/// cost only a marginal slice. The controller feeds one
/// (mean width, mean block cost) point per session per tick; the fit's
/// slope IS the live marginal token cost, the intercept the per-block
/// base — fitted from evidence, never assumed from a flag.
#[derive(Debug, Clone, Default)]
pub struct DraftCostModel {
    n: u64,
    sum_k: f64,
    sum_c: f64,
    sum_kk: f64,
    sum_kc: f64,
}

impl DraftCostModel {
    /// Fold one tick's (mean block width, mean block cost ms) point in.
    pub fn observe(&mut self, k_mean: f64, cost_ms: f64) {
        if !(k_mean.is_finite() && k_mean > 0.0 && cost_ms.is_finite() && cost_ms > 0.0) {
            return;
        }
        self.n += 1;
        self.sum_k += k_mean;
        self.sum_c += cost_ms;
        self.sum_kk += k_mean * k_mean;
        self.sum_kc += k_mean * cost_ms;
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// The fitted `(d_base, d_marginal)` in ms — only when the fit is
    /// warm AND has genuine spread in k (two distinct widths observed).
    /// All-one-width evidence — serial drafting included — cannot
    /// separate base from marginal, so it yields `None` and the planner
    /// keeps the classic `k·d` model bit-for-bit. The charge model is
    /// linear by construction in the wait engine, so two distinct widths
    /// already pin the line.
    pub fn fit(&self) -> Option<(f64, f64)> {
        if self.n < WARM_OBS {
            return None;
        }
        let n = self.n as f64;
        let det = n * self.sum_kk - self.sum_k * self.sum_k;
        // Spread gate: det is n² × variance(k); scale-relative epsilon.
        if det <= 1e-9 * (1.0 + self.sum_kk) {
            return None;
        }
        let marg = (n * self.sum_kc - self.sum_k * self.sum_c) / det;
        let base = (self.sum_c - marg * self.sum_k) / n;
        let (base, marg) = (base.max(0.0), marg.max(0.0));
        if base + marg <= 0.0 {
            return None; // pathological fit; keep the classic model
        }
        Some((base, marg))
    }
}

/// Live per-session evidence: acceptance, measured drafter step cost,
/// and the drafter block cost model.
#[derive(Debug, Clone)]
struct SessionEstimator {
    acceptance: Ewma,
    drafter_tpot_ms: Ewma,
    draft_cost: DraftCostModel,
}

impl SessionEstimator {
    fn new() -> Self {
        Self {
            acceptance: Ewma::new(EWMA_ALPHA),
            drafter_tpot_ms: Ewma::new(EWMA_ALPHA),
            draft_cost: DraftCostModel::default(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct Router {
    pub target: LatencyProfile,
    pub drafter: LatencyProfile,
    /// GPU budget for target servers (node size minus drafter).
    pub sp_budget: usize,
    /// Streaming acceptance estimate (§F.2 geometric fit, online).
    accepted: u64,
    rejected: u64,
    /// Live per-session estimators, keyed by pool session id.
    sessions: HashMap<u64, SessionEstimator>,
    /// Measured target per-task forward cost from the pool plane (the
    /// target replicas are identical, so one estimator serves the node).
    target_tpot_ms: Ewma,
}

impl Router {
    pub fn new(target: LatencyProfile, drafter: LatencyProfile, sp_budget: usize) -> Self {
        assert!(sp_budget >= 1);
        Self {
            target,
            drafter,
            sp_budget,
            accepted: 0,
            rejected: 0,
            sessions: HashMap::new(),
            target_tpot_ms: Ewma::new(EWMA_ALPHA),
        }
    }

    /// Live acceptance-rate estimate; NaN until observations arrive.
    pub fn acceptance_estimate(&self) -> f64 {
        let n = self.accepted + self.rejected;
        if n == 0 {
            return f64::NAN;
        }
        // mean accepted-run length = accepted/rejected; geometric fit.
        let mean_run = self.accepted as f64 / self.rejected.max(1) as f64;
        1.0 - 1.0 / (1.0 + mean_run)
    }

    /// Record a finished generation's verification outcomes.
    pub fn observe_run(&mut self, accepted: usize, rejections: usize) {
        self.accepted += accepted as u64;
        self.rejected += rejections as u64;
    }

    /// Record a finished generation's outcomes for `session` as well as
    /// the global counter — the static serving path's feed, so per-session
    /// estimates exist even when no controller runs.
    pub fn observe_session_run(&mut self, session: u64, accepted: usize, rejections: usize) {
        self.observe_run(accepted, rejections);
        self.observe_session_delta(session, accepted, rejections);
    }

    /// Fold one telemetry interval's accept/reject counts into `session`'s
    /// acceptance EWMA (and only there — the adaptive controller feeds
    /// this mid-generation while the global counter keeps its own
    /// post-run feed, so nothing is double-counted). Each settle event is
    /// a Bernoulli(p) draw under §F.2.1, so the interval ratio is the
    /// natural per-tick observation.
    pub fn observe_session_delta(&mut self, session: u64, accepted: usize, rejections: usize) {
        if accepted + rejections == 0 {
            return;
        }
        let ratio = accepted as f64 / (accepted + rejections) as f64;
        self.sessions
            .entry(session)
            .or_insert_with(SessionEstimator::new)
            .acceptance
            .observe(ratio);
    }

    /// Fold one measured drafter step cost (ms per drafter forward) into
    /// `session`'s latency estimator.
    pub fn observe_drafter_ms(&mut self, session: u64, ms_per_step: f64) {
        if !(ms_per_step.is_finite() && ms_per_step > 0.0) {
            return;
        }
        self.sessions
            .entry(session)
            .or_insert_with(SessionEstimator::new)
            .drafter_tpot_ms
            .observe(ms_per_step);
    }

    /// Fold one tick's drafter block observation (mean `draft_batch`
    /// width, mean block cost ms) into `session`'s block cost model —
    /// the evidence the marginal Equation-1 re-solve fits
    /// `d(k) = d_base + k·d_marginal` from.
    pub fn observe_drafter_block(&mut self, session: u64, k_mean: f64, block_cost_ms: f64) {
        self.sessions
            .entry(session)
            .or_insert_with(SessionEstimator::new)
            .draft_cost
            .observe(k_mean, block_cost_ms);
    }

    /// The fitted live `(d_base, d_marginal)` of `session`'s drafter
    /// block cost, ms — `None` until the fit has warm, width-diverse
    /// evidence (see [`DraftCostModel::fit`]).
    pub fn live_draft_cost_model(&self, session: u64) -> Option<(f64, f64)> {
        self.sessions.get(&session).and_then(|e| e.draft_cost.fit())
    }

    /// Fold one measured target per-task forward cost (ms, from the pool's
    /// dispatch plane) into the node-wide target latency estimator.
    pub fn observe_target_forward_ms(&mut self, ms_per_task: f64) {
        if !(ms_per_task.is_finite() && ms_per_task > 0.0) {
            return;
        }
        self.target_tpot_ms.observe(ms_per_task);
    }

    /// Drop a departed session's estimators.
    pub fn retire_session(&mut self, session: u64) {
        self.sessions.remove(&session);
    }

    /// Live acceptance estimate for `session`: its warm EWMA, else the
    /// global estimate, else a neutral prior.
    pub fn live_acceptance(&self, session: u64) -> f64 {
        if let Some(p) = self
            .sessions
            .get(&session)
            .filter(|e| e.acceptance.count() >= WARM_OBS)
            .and_then(|e| e.acceptance.get())
        {
            return p;
        }
        let global = self.acceptance_estimate();
        if global.is_finite() {
            global
        } else {
            ACCEPTANCE_PRIOR
        }
    }

    /// Live drafter step cost for `session`, ms: its warm EWMA, else the
    /// calibrated profile.
    pub fn live_drafter_tpot_ms(&self, session: u64) -> f64 {
        self.sessions
            .get(&session)
            .filter(|e| e.drafter_tpot_ms.count() >= WARM_OBS)
            .and_then(|e| e.drafter_tpot_ms.get())
            .unwrap_or(self.drafter.tpot_ms)
    }

    /// Live target per-task forward cost, ms: the warm pool-plane EWMA,
    /// else the calibrated profile.
    pub fn live_target_tpot_ms(&self) -> f64 {
        if self.target_tpot_ms.count() >= WARM_OBS {
            self.target_tpot_ms.get().unwrap_or(self.target.tpot_ms)
        } else {
            self.target.tpot_ms
        }
    }

    /// The operating point for an algorithm with the whole node to itself.
    pub fn plan(&self, algo: AlgoKind) -> Plan {
        self.plan_shared(algo, 1)
    }

    /// The operating point when `active_sessions` generations share the
    /// node: the SP budget is split evenly and Equation 1 is re-solved at
    /// the per-session share, so the lookahead/SP operating point adapts
    /// as sessions join and leave. A smaller share forces a larger
    /// lookahead (fewer, longer verification tasks per session) — the
    /// resource-vs-latency tradeoff of §3.1 at serving scale.
    ///
    /// This is the *floor* (evenly-split) share — the static planner's
    /// historical behavior, kept bit-identical as the adaptive plane's A/B
    /// control. The integer-division remainder it strands is handed out by
    /// [`plan_shared_all`](Self::plan_shared_all) (and, at live estimates,
    /// by the controller's water-filling).
    pub fn plan_shared(&self, algo: AlgoKind, active_sessions: usize) -> Plan {
        let share = (self.sp_budget / active_sessions.max(1)).max(1);
        self.plan_at(algo, share, self.target.tpot_ms, self.drafter.tpot_ms)
    }

    /// Per-slot static allocation over `active_sessions` sessions: the SP
    /// budget split as evenly as possible with the integer-division
    /// remainder dealt round-robin to the first slots (budget 10 over 4
    /// sessions → shares `[3, 3, 2, 2]`, never `[2, 2, 2, 2]` with two
    /// servers silently stranded), each slot's lookahead re-solved via
    /// Equation 1 at its share. Allocated SP sums to the budget whenever
    /// `sp_budget >= active_sessions`; below that every session still gets
    /// one server (the pool oversubscribes rather than starving anyone).
    pub fn plan_shared_all(&self, algo: AlgoKind, active_sessions: usize) -> Vec<Plan> {
        let n = active_sessions.max(1);
        let base = self.sp_budget / n;
        let rem = self.sp_budget % n;
        (0..n)
            .map(|slot| {
                let share = (base + usize::from(slot < rem)).max(1);
                self.plan_at(algo, share, self.target.tpot_ms, self.drafter.tpot_ms)
            })
            .collect()
    }

    /// Weighted static allocation: the SP budget split in proportion to
    /// per-slot fair-share weights (tenant weight × SLO multiplier) by
    /// largest-remainder apportionment, every slot floored at one server,
    /// each slot's lookahead re-solved via Equation 1 at its share. With
    /// uniform weights this reproduces
    /// [`plan_shared_all`](Self::plan_shared_all) exactly — untagged
    /// workloads keep the unweighted split bit-for-bit.
    pub fn plan_shared_weighted(&self, algo: AlgoKind, weights: &[f64]) -> Vec<Plan> {
        if weights.is_empty() {
            return self.plan_shared_all(algo, 0);
        }
        let w: Vec<f64> = weights
            .iter()
            .map(|&x| if x.is_finite() && x > 0.0 { x } else { 1.0 })
            .collect();
        let total: f64 = w.iter().sum();
        let quotas: Vec<f64> = w
            .iter()
            .map(|x| self.sp_budget as f64 * x / total)
            .collect();
        let mut shares: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
        let mut rem = self.sp_budget.saturating_sub(shares.iter().sum());
        // Largest fractional remainder first; ties to the earlier slot
        // (matching plan_shared_all's deal-to-the-first-slots rule).
        let mut order: Vec<usize> = (0..w.len()).collect();
        order.sort_by(|&a, &b| {
            let (fa, fb) = (quotas[a] - quotas[a].floor(), quotas[b] - quotas[b].floor());
            fb.partial_cmp(&fa).unwrap().then(a.cmp(&b))
        });
        for &i in &order {
            if rem == 0 {
                break;
            }
            shares[i] += 1;
            rem -= 1;
        }
        shares
            .into_iter()
            .map(|s| self.plan_at(algo, s.max(1), self.target.tpot_ms, self.drafter.tpot_ms))
            .collect()
    }

    /// The Equation-1 operating point for one session at live estimates:
    /// `share` servers, the measured target cost, and `session`'s measured
    /// drafter cost (each falling back to calibration until warm). The
    /// adaptive controller calls this once per session per tick.
    pub fn plan_live(&self, algo: AlgoKind, session: u64, share: usize) -> Plan {
        self.plan_live_with_hop(algo, session, share, 0.0)
    }

    /// [`plan_live`](Self::plan_live) for a session served by a remote
    /// node: a verification's effective latency is the forward cost plus
    /// the round-trip over the message plane (2 × the one-way `hop_ms`),
    /// so Equation 1 re-solves at the *inflated* target cost — a remote
    /// lane needs a larger lookahead (fewer, longer tasks) and caps at a
    /// higher useful SP than a local one with the same GPU. Local
    /// sessions pass 0 and get the plain `plan_live` bit-for-bit.
    pub fn plan_live_with_hop(
        &self,
        algo: AlgoKind,
        session: u64,
        share: usize,
        hop_ms: f64,
    ) -> Plan {
        let hop = if hop_ms.is_finite() && hop_ms > 0.0 { hop_ms } else { 0.0 };
        let target_ms = self.live_target_tpot_ms() + 2.0 * hop;
        // Prefer the fitted block cost model d(k) = d_base + k·d_marginal
        // when the session has width-diverse evidence (parallel drafting
        // live): a cheap marginal makes a block cheaper, so Equation 1
        // demands MORE concurrent servers at a given k — and the minimal
        // feasible lookahead grows with it. Without such evidence (serial
        // drafting, cold sessions) the classic k·d path below is taken
        // bit-for-bit.
        if algo == AlgoKind::Dsi {
            if let Some((base, marg)) = self.live_draft_cost_model(session) {
                return Self::plan_dsi_marginal(share, target_ms, base, marg);
            }
        }
        self.plan_at(algo, share, target_ms, self.live_drafter_tpot_ms(session))
    }

    /// Equation-1 planning core under the fitted marginal block cost
    /// model — the Dsi arm of [`plan_at`](Self::plan_at) with
    /// `k·d` replaced by `d_base + k·d_marginal`.
    fn plan_dsi_marginal(share: usize, target_ms: f64, d_base: f64, d_marg: f64) -> Plan {
        let sp = share
            .min(max_useful_sp_marginal(target_ms, d_base, d_marg))
            .max(1);
        let k = min_lookahead_for_sp_marginal(target_ms, d_base, d_marg, sp);
        Plan { lookahead: k, sp_degree: sp }
    }

    /// Equation-1 planning core at explicit rates.
    fn plan_at(&self, algo: AlgoKind, share: usize, target_ms: f64, drafter_ms: f64) -> Plan {
        match algo {
            AlgoKind::NonSi => Plan { lookahead: 1, sp_degree: 1 },
            AlgoKind::Si | AlgoKind::Pearl => Plan {
                // SI uses a single target server; lookahead 5 is the
                // standard practice the paper cites (and sweeps around).
                lookahead: 5,
                sp_degree: 1,
            },
            AlgoKind::Dsi => {
                // Don't allocate more target servers than can ever be
                // concurrently busy (§3.1).
                let sp = share.min(max_useful_sp(target_ms, drafter_ms)).max(1);
                let k = min_lookahead_for_sp(target_ms, drafter_ms, sp);
                Plan { lookahead: k, sp_degree: sp }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dsi_plan_satisfies_eq1() {
        let r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 7);
        let p = r.plan(AlgoKind::Dsi);
        assert!(crate::config::required_sp(30.0, 3.0, p.lookahead) <= p.sp_degree);
        assert!(p.sp_degree <= 7);
    }

    #[test]
    fn dsi_plan_caps_at_useful_sp() {
        // Slow drafter (50%): only 2 target servers can ever be busy.
        let r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(15.0), 7);
        let p = r.plan(AlgoKind::Dsi);
        assert_eq!(p.sp_degree, 2);
        assert_eq!(p.lookahead, 1);
    }

    #[test]
    fn acceptance_estimator_converges() {
        let mut r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 7);
        assert!(r.acceptance_estimate().is_nan());
        // p=0.8 -> mean run 4 accepted per rejection
        r.observe_run(4000, 1000);
        assert!((r.acceptance_estimate() - 0.8).abs() < 0.01);
    }

    #[test]
    fn nonsi_plan_trivial() {
        let r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 7);
        let p = r.plan(AlgoKind::NonSi);
        assert_eq!((p.lookahead, p.sp_degree), (1, 1));
    }

    #[test]
    fn shared_plan_splits_budget_and_grows_lookahead() {
        let r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 8);
        let solo = r.plan_shared(AlgoKind::Dsi, 1);
        let quad = r.plan_shared(AlgoKind::Dsi, 4);
        assert!(quad.sp_degree <= solo.sp_degree);
        assert!(quad.sp_degree <= 2, "8-way budget split 4 ways");
        // Each per-session plan still satisfies Equation 1 at its share.
        assert!(crate::config::required_sp(30.0, 3.0, quad.lookahead) <= quad.sp_degree);
        // Fewer servers per session => at least as much lookahead.
        assert!(quad.lookahead >= solo.lookahead);
    }

    #[test]
    fn shared_plan_never_starves_a_session() {
        // More sessions than budget: everyone still gets one server.
        let r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 4);
        let p = r.plan_shared(AlgoKind::Dsi, 9);
        assert_eq!(p.sp_degree, 1);
        assert!(p.lookahead >= 1);
    }

    /// The integer-division fix: budget 10 over 4 sessions must allocate
    /// [3, 3, 2, 2] — allocated SP sums to the budget, no remainder
    /// servers stranded — with every slot's lookahead satisfying
    /// Equation 1 at its share.
    #[test]
    fn shared_all_distributes_the_remainder() {
        let r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 10);
        let plans = r.plan_shared_all(AlgoKind::Dsi, 4);
        let shares: Vec<usize> = plans.iter().map(|p| p.sp_degree).collect();
        assert_eq!(shares, vec![3, 3, 2, 2]);
        assert_eq!(shares.iter().sum::<usize>(), 10, "budget partially stranded");
        for p in &plans {
            assert!(crate::config::required_sp(30.0, 3.0, p.lookahead) <= p.sp_degree);
        }
        // The floor plan (the A/B control) is the last slot's.
        assert_eq!(r.plan_shared(AlgoKind::Dsi, 4).sp_degree, 2);

        // Budget below the session count: one server each, nobody starved.
        let tight = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 4);
        let plans = tight.plan_shared_all(AlgoKind::Dsi, 9);
        assert_eq!(plans.len(), 9);
        assert!(plans.iter().all(|p| p.sp_degree == 1));
    }

    /// Weighted apportionment: uniform weights reproduce the unweighted
    /// split exactly; skewed weights shift whole servers toward the heavy
    /// tenant without stranding budget or starving the light one.
    #[test]
    fn shared_weighted_apportions_by_weight() {
        let r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 10);
        // Uniform weights == plan_shared_all, bit for bit.
        let even = r.plan_shared_weighted(AlgoKind::Dsi, &[1.0; 4]);
        assert_eq!(even, r.plan_shared_all(AlgoKind::Dsi, 4));

        // 3:1:1 over a budget of 10 → quotas [6, 2, 2], exact.
        let skew = r.plan_shared_weighted(AlgoKind::Dsi, &[3.0, 1.0, 1.0]);
        let shares: Vec<usize> = skew.iter().map(|p| p.sp_degree).collect();
        assert_eq!(shares.iter().sum::<usize>(), 10, "budget partially stranded");
        assert!(shares[0] > shares[1], "heavy tenant must get more servers");
        assert_eq!(shares[1], shares[2], "equal weights, equal shares");
        for p in &skew {
            assert!(crate::config::required_sp(30.0, 3.0, p.lookahead) <= p.sp_degree);
        }

        // Extreme skew never starves the light tenant, and junk weights
        // (zero / NaN) are treated as neutral rather than panicking.
        let harsh = r.plan_shared_weighted(AlgoKind::Dsi, &[100.0, 0.0, f64::NAN]);
        assert!(harsh.iter().all(|p| p.sp_degree >= 1));
        assert!(harsh[0].sp_degree >= harsh[1].sp_degree);
    }

    /// Live estimators fall back to calibration until warm, then track
    /// the measured rates — and `plan_live` re-solves Equation 1 at them.
    #[test]
    fn live_estimates_fall_back_then_track() {
        let mut r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 7);
        // Cold: calibrated fallbacks and the neutral acceptance prior.
        assert_eq!(r.live_target_tpot_ms(), 30.0);
        assert_eq!(r.live_drafter_tpot_ms(42), 3.0);
        assert_eq!(r.live_acceptance(42), 0.5);
        let boot = r.plan_live(AlgoKind::Dsi, 42, 2);
        assert_eq!(boot, r.plan_shared(AlgoKind::Dsi, 3), "cold plan_live != calibrated plan");

        // One observation is still below the warm-up gate.
        r.observe_drafter_ms(42, 9.0);
        assert_eq!(r.live_drafter_tpot_ms(42), 3.0);

        // Warm: the measured drafter is 3x slower than calibrated; the
        // Equation-1 lookahead at the same share must shrink with it.
        for _ in 0..8 {
            r.observe_drafter_ms(42, 9.0);
            r.observe_target_forward_ms(30.0);
            r.observe_session_delta(42, 1, 4); // p ~ 0.2
        }
        assert!((r.live_drafter_tpot_ms(42) - 9.0).abs() < 1e-6);
        assert!((r.live_acceptance(42) - 0.2).abs() < 1e-6);
        let live = r.plan_live(AlgoKind::Dsi, 42, 2);
        assert!(live.lookahead < boot.lookahead, "slower drafter must lower k at fixed SP");
        assert!(crate::config::required_sp(30.0, 9.0, live.lookahead) <= live.sp_degree);

        // Another session stays on calibration; retiring drops the state.
        assert_eq!(r.live_drafter_tpot_ms(7), 3.0);
        r.retire_session(42);
        assert_eq!(r.live_drafter_tpot_ms(42), 3.0);
    }

    /// The fitted block cost model: inert on width-less (serial)
    /// evidence — the classic `k·d` plan survives bit-for-bit — and
    /// near-exact on width-diverse linear evidence.
    #[test]
    fn draft_cost_fit_warms_only_on_width_diverse_evidence() {
        let mut r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 8);

        // Serial evidence: every block width 1. No spread ⇒ no fit ⇒
        // plan_live identical to a router that never saw blocks.
        for _ in 0..6 {
            r.observe_drafter_block(9, 1.0, 3.0);
        }
        assert!(r.live_draft_cost_model(9).is_none());
        let classic = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 8);
        assert_eq!(
            r.plan_live(AlgoKind::Dsi, 9, 4),
            classic.plan_live(AlgoKind::Dsi, 9, 4),
            "serial block evidence must not move the plan"
        );

        // Width-diverse evidence on the exact line c(k) = 2 + 0.5k.
        for (k, c) in [(1.0, 2.5), (4.0, 4.0), (8.0, 6.0)] {
            r.observe_drafter_block(42, k, c);
        }
        let (base, marg) = r.live_draft_cost_model(42).expect("fit must be warm");
        assert!((base - 2.0).abs() < 1e-6, "fitted base {base}");
        assert!((marg - 0.5).abs() < 1e-6, "fitted marginal {marg}");

        // Junk observations are dropped, not folded.
        r.observe_drafter_block(42, f64::NAN, 1.0);
        r.observe_drafter_block(42, 2.0, -1.0);
        let (b2, m2) = r.live_draft_cost_model(42).unwrap();
        assert_eq!((b2, m2), (base, marg));

        r.retire_session(42);
        assert!(r.live_draft_cost_model(42).is_none());
    }

    /// Marginal Equation-1 property: across a grid of (target, base,
    /// marginal, share), every plan the marginal path emits is feasible
    /// under the marginal block cost and capped at the marginal useful
    /// maximum — and a cheaper marginal never *shrinks* the lookahead at
    /// a fixed share (deep speculation becomes nearly free; the planner
    /// must take it).
    #[test]
    fn marginal_plan_satisfies_marginal_eq1() {
        use crate::config::{max_useful_sp_marginal, required_sp_marginal};
        for &t in &[10.0, 30.0, 100.0] {
            for &base in &[0.5, 2.0, 5.0] {
                for &marg in &[0.1, 0.5, 2.0] {
                    for share in 1..=8usize {
                        let mut r = Router::new(
                            LatencyProfile::uniform(t),
                            LatencyProfile::uniform(3.0),
                            8,
                        );
                        // Two exact points pin the (linear) charge line.
                        r.observe_drafter_block(1, 1.0, base + marg);
                        r.observe_drafter_block(1, 5.0, base + 5.0 * marg);
                        let (b, m) = r.live_draft_cost_model(1).expect("two-point fit");
                        assert!((b - base).abs() < 1e-6 && (m - marg).abs() < 1e-6);
                        let p = r.plan_live(AlgoKind::Dsi, 1, share);
                        assert!(
                            required_sp_marginal(t, base, marg, p.lookahead) <= p.sp_degree,
                            "infeasible plan {p:?} at t={t} base={base} marg={marg} share={share}"
                        );
                        assert!(
                            p.sp_degree
                                <= share.min(max_useful_sp_marginal(t, base, marg)).max(1)
                        );
                    }
                }
            }
        }

        let k_at = |marg: f64| {
            let mut r =
                Router::new(LatencyProfile::uniform(40.0), LatencyProfile::uniform(4.0), 6);
            r.observe_drafter_block(1, 1.0, 4.0 + marg);
            r.observe_drafter_block(1, 6.0, 4.0 + 6.0 * marg);
            r.plan_live(AlgoKind::Dsi, 1, 6).lookahead
        };
        assert!(
            k_at(0.25) >= k_at(4.0),
            "a cheaper marginal token must not shrink the planned lookahead"
        );
    }

    /// A remote lane's hop inflates the effective target cost (forward +
    /// round-trip): zero/junk hops are bit-identical to `plan_live`, and
    /// a real hop can only grow the Equation-1 lookahead at a fixed
    /// share, with the plan still feasible at the inflated cost.
    #[test]
    fn plan_live_hop_inflates_the_target_cost() {
        let r = Router::new(LatencyProfile::uniform(30.0), LatencyProfile::uniform(3.0), 8);
        let local = r.plan_live(AlgoKind::Dsi, 1, 4);
        assert_eq!(r.plan_live_with_hop(AlgoKind::Dsi, 1, 4, 0.0), local);
        assert_eq!(r.plan_live_with_hop(AlgoKind::Dsi, 1, 4, f64::NAN), local);
        assert_eq!(r.plan_live_with_hop(AlgoKind::Dsi, 1, 4, -3.0), local);

        let remote = r.plan_live_with_hop(AlgoKind::Dsi, 1, 4, 15.0);
        assert!(
            remote.lookahead >= local.lookahead,
            "a remote lane must not plan a smaller lookahead"
        );
        // Feasible at the inflated effective target cost 30 + 2*15.
        assert!(crate::config::required_sp(60.0, 3.0, remote.lookahead) <= remote.sp_degree);
    }
}
