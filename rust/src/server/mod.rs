//! The serving front: request handling on top of the DSI coordinator.
//!
//! A downstream user deploys DSI behind this layer: requests arrive (open
//! or closed loop) into an admission queue, up to `max_sessions`
//! generations run concurrently on OS threads, the [`router`] picks each
//! generation's operating point (lookahead / SP split via Equation 1 at
//! the *per-session* share of the node's SP budget, re-planned as sessions
//! join and leave), the generation runs the selected algorithm — DSI
//! sessions share one [`TargetPool`] — and [`metrics`] aggregates
//! TTFT/TPOT/throughput over the true wall-clock span.
//!
//! Admission is **continuous** by default: the slot a completed
//! generation frees is refilled by the next arrived request immediately,
//! sessions join and leave the shared pool mid-flight, and (under
//! `--adaptive`) every membership change kicks the controller so SP
//! shares re-water-fill within one tick — with queued speculation beyond
//! a shrunken share preemptively reclaimed rather than drained. The
//! [`AdmissionMode::RunToCompletion`] gang baseline (admit a wave of
//! `max_sessions`, barrier until the whole wave finishes, repeat) is kept
//! as the A/B control the sustained-load bench measures against.

pub mod controller;
pub mod metrics;
pub mod router;

use crate::config::AlgoKind;
use crate::coordinator::node::{ServingPool, ShardedPool};
use crate::coordinator::pool::relock;
use crate::coordinator::{
    faulty_factory, run_nonsi_with, run_si_with, DrafterSpec, DsiSession, FaultPlan, FaultStats,
    LmServer, OnlineConfig, OnlineOutcome, SchedPolicy, ServerFactory, ServerRole, TargetPool,
};
use crate::runtime::kv::StoreStats;
use crate::runtime::tokenizer;
use crate::workload::{Request, SloClass};
use controller::{Controller, ControllerStats, SessionRegistry, TickSignal};
use metrics::Metrics;
use router::{Plan, Router};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

/// How the scheduler refills freed `max_sessions` slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Continuous batching (default): the instant a generation completes,
    /// the next arrived request is dispatched into its slot — sessions
    /// join and leave the shared pool mid-flight.
    Continuous,
    /// Gang scheduling: admit a wave of up to `max_sessions` requests,
    /// barrier until the *whole wave* completes, then admit the next.
    /// Freed slots idle out the wave tail — the classic serving baseline
    /// continuous batching beats on tail TTFT; kept as the A/B control.
    RunToCompletion,
}

impl AdmissionMode {
    /// Parse a launcher flag value (`continuous` | `rtc`).
    pub fn parse(s: &str) -> Option<AdmissionMode> {
        match s {
            "continuous" => Some(AdmissionMode::Continuous),
            "rtc" | "run-to-completion" => Some(AdmissionMode::RunToCompletion),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionMode::Continuous => "continuous",
            AdmissionMode::RunToCompletion => "rtc",
        }
    }
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub text: String,
    /// Wall ms from dispatch to first output token.
    pub ttft_ms: f64,
    /// Wall ms for the whole generation.
    pub wall_ms: f64,
    /// Queueing delay before dispatch, ms.
    pub queue_ms: f64,
    pub algo: AlgoKind,
    /// Lookahead the router planned for this generation.
    pub lookahead: usize,
    /// SP share the router planned for this generation.
    pub sp_degree: usize,
    /// Tenant tag carried through from the request.
    pub tenant: u32,
    /// Fair-share weight carried through from the request.
    pub weight: f64,
    /// SLO class carried through from the request.
    pub slo: SloClass,
}

/// What one scheduler worker holds to execute generations. Constructed
/// lazily on the worker's first job so idle workers load no models.
enum Backend {
    /// A DSI session registered on the server's shared target pool.
    Dsi(DsiSession),
    /// SI (and PEARL, served through the SI path): one target, one drafter.
    Paired { target: Box<dyn LmServer>, drafter: Box<dyn LmServer> },
    /// Non-SI: a single target server.
    Single { target: Box<dyn LmServer> },
}

impl Backend {
    /// `worker_id` is the scheduler worker constructing this backend:
    /// threaded into the factory so concurrent workers get distinct
    /// `(role, id)` pairs — a factory that seeds per-server state by id
    /// must never see two live servers aliasing the same stream. (DSI
    /// backends identify their drafter by pool session id instead, which
    /// is unique across workers by construction.)
    fn new(
        algo: AlgoKind,
        factory: &ServerFactory,
        pool: Option<&ServingPool>,
        worker_id: usize,
        drafters: &[DrafterSpec],
    ) -> Self {
        match algo {
            AlgoKind::Dsi => {
                match pool.expect("DSI serving requires the shared target pool") {
                    ServingPool::Single(pool) => {
                        Backend::Dsi(DsiSession::new_with_portfolio(pool, factory, drafters))
                    }
                    ServingPool::Sharded(pool) => {
                        Backend::Dsi(DsiSession::new_sharded_with_portfolio(
                            pool, factory, drafters,
                        ))
                    }
                }
            }
            // PEARL's online coordinator is not implemented; its router
            // plan (one target + one drafter, §Router) degrades to
            // blocking SI, so serve it honestly through the SI path
            // rather than silently running non-SI. The discrete-event
            // simulator has the faithful PEARL model.
            AlgoKind::Si | AlgoKind::Pearl => Backend::Paired {
                target: factory(ServerRole::Target, worker_id),
                drafter: factory(ServerRole::Drafter, worker_id),
            },
            AlgoKind::NonSi => {
                Backend::Single { target: factory(ServerRole::Target, worker_id) }
            }
        }
    }

    fn run(&mut self, cfg: &OnlineConfig) -> OnlineOutcome {
        match self {
            Backend::Dsi(session) => session.generate(cfg),
            Backend::Paired { target, drafter } => {
                run_si_with(target.as_mut(), drafter.as_mut(), cfg)
            }
            Backend::Single { target } => run_nonsi_with(target.as_mut(), cfg),
        }
    }
}

/// Serving engine: a multi-session scheduler. Requests are admitted in
/// arrival order and executed by up to `max_sessions` worker threads;
/// DSI generations contend for one shared [`TargetPool`] sized to the
/// node's SP budget. `max_sessions = 1` (the default) reproduces the
/// single-generation regime where DSI spends the whole node on
/// speculation parallelism.
pub struct Server {
    factory: ServerFactory,
    router: Arc<Mutex<Router>>,
    metrics: Arc<Mutex<Metrics>>,
    algo: AlgoKind,
    max_speculation_depth: usize,
    /// Concurrent generations admitted at once.
    max_sessions: usize,
    /// Shared target-pool size (defaults to the router's SP budget).
    /// Under node sharding this is the *fleet* budget, split evenly
    /// across nodes.
    pool_size: usize,
    /// Node shards in the serving plane (default 1: the classic
    /// single-node pool; >= 2 shards the pool behind the RPC-shaped
    /// message plane with simulated inter-node hops).
    nodes: usize,
    /// Modeled one-way hop to every non-local node, ms (node 0 is local
    /// and always pays 0).
    node_hop_ms: f64,
    /// Pool scheduling policy (affinity by default; FIFO is the A/B
    /// control, now selectable from the launcher via `--sched-policy`).
    sched_policy: SchedPolicy,
    /// Micro-batch drain cap for the pool workers (1 = serial plane).
    /// Under the adaptive controller this is the cap's *ceiling*; the
    /// admission-aware sizing moves below it at runtime.
    batch_cap: usize,
    /// Run the adaptive control plane (DSI only): live estimators,
    /// Equation-1 replanning, uneven SP water-filling, admission-aware
    /// batch sizing. Off by default — the static planner is the A/B
    /// control and stays bit-identical to the pre-adaptive server.
    adaptive: bool,
    /// Slot-refill discipline (continuous by default; run-to-completion
    /// is the gang-scheduled A/B baseline).
    admission: AdmissionMode,
    /// Per-token latency SLO the admission-aware batch sizing protects
    /// (infinite = batch for throughput alone).
    slo_ms: f64,
    /// Operator override for the sessions' verify deadline, ms
    /// (non-positive = auto-derive from the live target-TPOT estimate).
    verify_deadline_ms: f64,
    /// Drafter portfolio (`--drafters`): each DSI session starts on the
    /// calibrated-best member and the adaptive controller may switch it
    /// at restart boundaries. Empty = the factory's single drafter.
    drafters: Vec<DrafterSpec>,
    /// Enable parallel multi-token drafting (`draft_batch` at the live
    /// lookahead instead of one token per call). Off by default — the
    /// serial drafter loop is the bit-identical A/B control.
    parallel_draft: bool,
    /// Seeded fault-injection schedule (`--fault-spec`). `None` injects
    /// nothing; supervision still covers organic faults.
    fault_plan: Option<Arc<FaultPlan>>,
    /// Recovery-side fault gauges (deadline expiries, drafter
    /// stops/restarts, degradations), shared with every DSI session and
    /// attached to metrics at construction.
    fault_stats: Arc<FaultStats>,
    /// Controller tick period.
    control_interval: Duration,
    /// Controller counters/gauges, attached to metrics at construction so
    /// snapshots always carry the fields (idle-zero when not adaptive).
    controller_stats: Arc<ControllerStats>,
    /// The serving plane's target workers — one shared pool, or a node
    /// fleet behind the message plane; lazily built on the first DSI
    /// serve and persistent across `serve` calls (model loading / HLO
    /// compilation happens once per worker, not once per request).
    pool: Option<ServingPool>,
    /// Generations currently in flight.
    active: Arc<AtomicUsize>,
    /// Server-lifetime clock for metrics span stamps: dispatch/completion
    /// times from different `serve` calls must share one epoch, or the
    /// throughput span would mix incompatible clocks.
    epoch: Instant,
}

impl Server {
    pub fn new(factory: ServerFactory, router: Router, algo: AlgoKind) -> Self {
        let pool_size = router.sp_budget;
        let active = Arc::new(AtomicUsize::new(0));
        let controller_stats = Arc::new(ControllerStats::default());
        let fault_stats = Arc::new(FaultStats::default());
        let mut metrics = Metrics::new();
        metrics.attach_active_gauge(active.clone());
        metrics.attach_controller_stats(controller_stats.clone());
        metrics.attach_fault_stats(fault_stats.clone());
        Self {
            factory,
            router: Arc::new(Mutex::new(router)),
            metrics: Arc::new(Mutex::new(metrics)),
            algo,
            max_speculation_depth: 24,
            max_sessions: 1,
            pool_size,
            nodes: 1,
            node_hop_ms: 0.0,
            sched_policy: SchedPolicy::Affinity,
            batch_cap: crate::coordinator::pool::BATCH_CAP_DEFAULT,
            adaptive: false,
            admission: AdmissionMode::Continuous,
            slo_ms: f64::INFINITY,
            verify_deadline_ms: 0.0,
            drafters: Vec::new(),
            parallel_draft: false,
            fault_plan: None,
            fault_stats,
            control_interval: Duration::from_millis(25),
            controller_stats,
            pool: None,
            active,
            epoch: Instant::now(),
        }
    }

    pub fn with_max_depth(mut self, depth: usize) -> Self {
        self.max_speculation_depth = depth;
        self
    }

    /// Admit up to `n` concurrent generations (default 1).
    pub fn with_max_sessions(mut self, n: usize) -> Self {
        self.max_sessions = n.max(1);
        self
    }

    /// Size the shared target pool (default: the router's SP budget).
    /// Takes effect before the pool is first built. The router's SP
    /// budget is updated to match, so Equation-1 plans never promise SP
    /// shares the pool cannot deliver.
    pub fn with_pool_size(mut self, n: usize) -> Self {
        self.pool_size = n.max(1);
        relock(&self.router).sp_budget = self.pool_size;
        self
    }

    /// Shard the serving plane across `n` simulated nodes (default 1).
    /// The fleet keeps the same *total* worker budget — each node gets
    /// `pool_size / n` workers (floor, min 1) — while admission
    /// concurrency scales to `max_sessions × n`, which is how a 2-node
    /// plane beats 1 node at equal total workers: SP has diminishing
    /// returns per Equation 1, concurrency does not. Takes effect before
    /// the pool is first built.
    pub fn with_nodes(mut self, n: usize) -> Self {
        self.nodes = n.max(1);
        self
    }

    /// Modeled one-way network hop to every non-local node, ms
    /// (meaningful only with `--nodes >= 2`; non-finite or non-positive
    /// values mean free hops). Remote sessions' verify deadlines and
    /// Equation-1 plans are widened by the round trip automatically.
    pub fn with_node_hop_ms(mut self, ms: f64) -> Self {
        self.node_hop_ms = if ms.is_finite() && ms > 0.0 { ms } else { 0.0 };
        self
    }

    /// Select the shared pool's scheduling policy (default affinity;
    /// FIFO is the A/B control). Takes effect before the pool is built.
    pub fn with_sched_policy(mut self, policy: SchedPolicy) -> Self {
        self.sched_policy = policy;
        self
    }

    /// Cap the pool workers' micro-batch drains (default
    /// [`BATCH_CAP_DEFAULT`](crate::coordinator::pool::BATCH_CAP_DEFAULT);
    /// 1 reproduces the serial verification plane). Takes effect before
    /// the pool is built.
    pub fn with_batch_cap(mut self, cap: usize) -> Self {
        self.batch_cap = cap.max(1);
        self
    }

    /// Run (or not) the adaptive control plane: live per-session
    /// estimators drive Equation-1 replanning, water-filled uneven SP
    /// shares, and admission-aware batch sizing while generations are in
    /// flight. Applies to DSI serving; the static planner (`false`, the
    /// default) remains the A/B control with plans and outputs
    /// bit-identical to the pre-adaptive server.
    pub fn with_adaptive(mut self, on: bool) -> Self {
        self.adaptive = on;
        self
    }

    /// Select the slot-refill discipline (default
    /// [`AdmissionMode::Continuous`]; run-to-completion gang scheduling
    /// is the A/B baseline the sustained-load bench measures against).
    pub fn with_admission_mode(mut self, mode: AdmissionMode) -> Self {
        self.admission = mode;
        self
    }

    /// Per-token latency SLO for the admission-aware batch sizing, ms.
    /// Non-positive or non-finite disables the SLO clamp (batching then
    /// follows queue depth alone).
    pub fn with_slo_ms(mut self, ms: f64) -> Self {
        self.slo_ms = if ms.is_finite() && ms > 0.0 { ms } else { f64::INFINITY };
        self
    }

    /// Adaptive-controller tick period, ms (clamped to >= 1ms).
    pub fn with_control_interval_ms(mut self, ms: f64) -> Self {
        self.control_interval = Duration::from_secs_f64(ms.max(1.0) / 1e3);
        self
    }

    /// Override the sessions' verify deadline (`--verify-deadline-ms`).
    /// Non-positive or non-finite restores auto-derivation from the live
    /// target-TPOT estimate.
    pub fn with_verify_deadline_ms(mut self, ms: f64) -> Self {
        self.verify_deadline_ms = if ms.is_finite() && ms > 0.0 { ms } else { 0.0 };
        self
    }

    /// Install a drafter portfolio (`--drafters`): DSI sessions start on
    /// the calibrated-best member (lowest prior cost per accepted token)
    /// and, under `--adaptive`, the controller re-scores members at live
    /// acceptance/TPOT each tick and switches a session's drafter at a
    /// restart boundary when a challenger wins by the hysteresis margin.
    /// The factory must realize portfolio members by drafter id (see
    /// `drafter_member`); the wait engine's `factory_configured` does.
    pub fn with_drafters(mut self, specs: Vec<DrafterSpec>) -> Self {
        self.drafters = specs;
        self
    }

    /// Enable parallel multi-token drafting: the session drafter fills
    /// its whole lookahead block with one `draft_batch` call instead of
    /// one forward per token. Lossless by construction (the batch
    /// contract is bit-identical to serial greedy drafting); pair with a
    /// `--draft-token-cost-frac < 1` engine to model the latency win.
    pub fn with_parallel_draft(mut self, on: bool) -> Self {
        self.parallel_draft = on;
        self
    }

    /// Install a seeded fault-injection schedule (`--fault-spec`): every
    /// server built for this serve is fault-decorated, the pool's send
    /// path consults the plan, and `faults_injected` appears in
    /// snapshots. Takes effect before the pool is first built.
    pub fn with_fault_plan(mut self, plan: Arc<FaultPlan>) -> Self {
        relock(&self.metrics).attach_fault_plan(plan.clone());
        self.fault_plan = Some(plan);
        self
    }

    /// The recovery-side fault gauges (shared with every DSI session).
    pub fn fault_stats(&self) -> Arc<FaultStats> {
        self.fault_stats.clone()
    }

    /// Attach a settled-block store's counters so metrics snapshots
    /// report its eviction pressure (callable once per store — e.g. the
    /// target and drafter stores of the real engine).
    pub fn attach_store_stats(&self, stats: Arc<StoreStats>) {
        relock(&self.metrics).attach_store_stats(stats);
    }

    /// Live acceptance estimate from the router (§F.2 online variant).
    pub fn acceptance_estimate(&self) -> f64 {
        relock(&self.router).acceptance_estimate()
    }

    /// Point-in-time metrics summary.
    pub fn metrics_snapshot(&self) -> metrics::Snapshot {
        relock(&self.metrics).snapshot()
    }

    /// Generations currently in flight.
    pub fn active_sessions(&self) -> usize {
        self.active.load(Ordering::Acquire)
    }

    /// Serve a full workload; honors arrival times (open loop) by waiting.
    /// Responses are returned in request order.
    pub fn serve(&mut self, requests: &[Request]) -> Vec<Response> {
        if requests.is_empty() {
            return Vec::new();
        }
        // With a fault plan installed, every server built below — pool
        // workers AND session drafters — is fault-decorated; without one
        // this is the factory itself (zero-cost, bit-identical path).
        let factory_eff: ServerFactory = match &self.fault_plan {
            Some(plan) => faulty_factory(self.factory.clone(), plan.clone()),
            None => self.factory.clone(),
        };
        if self.algo == AlgoKind::Dsi && self.pool.is_none() {
            let pool = if self.nodes >= 2 {
                // Sharded plane: the fleet splits the worker budget evenly
                // (floor, min 1 per node) behind the message plane.
                let wpn = (self.pool_size / self.nodes).max(1);
                let sharded = Arc::new(ShardedPool::new(
                    &factory_eff,
                    self.nodes,
                    wpn,
                    self.sched_policy,
                    self.batch_cap,
                    self.fault_plan.clone(),
                    self.node_hop_ms,
                ));
                // The realized fleet size may round below the requested
                // budget; keep Equation-1 plans honest about what the
                // pool can actually deliver.
                relock(&self.router).sp_budget = sharded.size();
                ServingPool::Sharded(sharded)
            } else {
                ServingPool::Single(Arc::new(TargetPool::new_with_faults(
                    &factory_eff,
                    self.pool_size,
                    self.sched_policy,
                    self.batch_cap,
                    self.fault_plan.clone(),
                )))
            };
            // Surface the pool's queue-wait / dispatch-overhead counters
            // in metrics snapshots.
            relock(&self.metrics).attach_pool_stats(pool.stats());
            self.pool = Some(pool);
        }
        // `max_sessions` is a per-node admission limit: a sharded DSI
        // plane runs up to `max_sessions × nodes` concurrent generations.
        let session_slots = self.max_sessions
            * if self.algo == AlgoKind::Dsi { self.nodes } else { 1 };
        let n_workers = session_slots.min(requests.len());

        // The adaptive control plane: one controller thread per serve
        // call, re-planning live while the workers generate. It touches
        // only Arc-shared state (router, session registry, pool knobs),
        // so it runs outside the worker scope and is joined after the
        // scope drains. Statically-planned serves spawn nothing.
        let registry: Option<SessionRegistry> = (self.adaptive
            && self.algo == AlgoKind::Dsi)
            .then(|| Arc::new(Mutex::new(HashMap::new())));
        // Membership signal: admissions/completions kick the controller
        // out of its inter-tick sleep so shares re-water-fill within one
        // tick of every membership change, not a full interval later.
        let tick_signal: Option<Arc<TickSignal>> =
            registry.as_ref().map(|_| Arc::new(TickSignal::new()));
        let ctl_stop = Arc::new(AtomicBool::new(false));
        let ctl_thread = registry.as_ref().map(|reg| {
            let mut ctl = Controller::new(
                self.router.clone(),
                reg.clone(),
                self.pool.clone().expect("DSI serving built the pool"),
                self.controller_stats.clone(),
                self.slo_ms,
                self.batch_cap,
            );
            ctl.set_portfolio(self.drafters.clone());
            let stop = ctl_stop.clone();
            let interval = self.control_interval;
            let sig = tick_signal.clone().expect("signal built with registry");
            std::thread::spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    // Snapshot the epoch *before* ticking: a kick landing
                    // mid-tick shortens the following wait instead of
                    // being lost.
                    let seen = sig.epoch();
                    ctl.tick();
                    let _ = sig.wait_past(seen, interval);
                }
            })
        });
        let adaptive = self.adaptive;
        let admission = self.admission;
        let verify_deadline_ms = self.verify_deadline_ms;
        let parallel_draft = self.parallel_draft;

        // Admission order: by arrival time (stable on ties).
        let mut order: Vec<usize> = (0..requests.len()).collect();
        order.sort_by(|&a, &b| {
            requests[a]
                .arrival_ms
                .partial_cmp(&requests[b].arrival_ms)
                .unwrap_or(std::cmp::Ordering::Equal)
        });

        let (job_tx, job_rx) = channel::<usize>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (resp_tx, resp_rx) = channel::<(usize, Response)>();
        // Completion counter + condvar: the run-to-completion barrier
        // (admission waits for the whole wave) — idle under continuous.
        let completed: Arc<(Mutex<usize>, Condvar)> =
            Arc::new((Mutex::new(0), Condvar::new()));
        // Arrival pacing and queueing delay are relative to this call's
        // start; metrics span stamps use the server-lifetime epoch so
        // repeated `serve` calls accumulate on one clock.
        let t0 = Instant::now();
        let epoch = self.epoch;
        let algo = self.algo;
        let depth = self.max_speculation_depth;

        std::thread::scope(|s| {
            for wid in 0..n_workers {
                let job_rx = job_rx.clone();
                let resp_tx = resp_tx.clone();
                let factory = factory_eff.clone();
                let fault_stats = self.fault_stats.clone();
                let router = self.router.clone();
                let metrics = self.metrics.clone();
                let active = self.active.clone();
                let pool = self.pool.clone();
                let registry = registry.clone();
                let tick_signal = tick_signal.clone();
                let ctl_stats = self.controller_stats.clone();
                let completed = completed.clone();
                let drafters = self.drafters.clone();
                s.spawn(move || {
                    // Lazy: a worker that never receives a job never
                    // loads models or spawns a drafter.
                    let mut backend: Option<Backend> = None;
                    loop {
                        // Take the next admitted request; release the
                        // queue lock before generating.
                        let idx = match relock(&job_rx).recv() {
                            Ok(i) => i,
                            Err(_) => break,
                        };
                        let req = &requests[idx];
                        let dispatched_ms = t0.elapsed().as_secs_f64() * 1e3;
                        let queue_ms = (dispatched_ms - req.arrival_ms).max(0.0);
                        let n_active = active.fetch_add(1, Ordering::AcqRel) + 1;
                        relock(&metrics)
                            .note_dispatch_at(epoch.elapsed().as_secs_f64() * 1e3);

                        // Re-plan the operating point at the current
                        // session count: the SP budget is a shared
                        // resource (Equation 1 at the per-session share).
                        // Adaptive boot plans take the remainder-aware
                        // slot (integer-division leftovers are dispatched,
                        // not stranded, until the first control tick
                        // water-fills properly); the static path keeps the
                        // historical floor split as the bit-identical A/B
                        // control.
                        let plan: Plan = {
                            let r = relock(&router);
                            if adaptive {
                                r.plan_shared_all(algo, n_active)[0]
                            } else {
                                r.plan_shared(algo, n_active)
                            }
                        };
                        let cfg = OnlineConfig {
                            prompt: req.prompt.clone(),
                            n_tokens: req.max_new_tokens,
                            lookahead: plan.lookahead,
                            sp_degree: plan.sp_degree,
                            max_speculation_depth: depth,
                        };
                        if backend.is_none() {
                            let mut b =
                                Backend::new(algo, &factory, pool.as_ref(), wid, &drafters);
                            if let Backend::Dsi(sess) = &mut b {
                                // Wire the fault plane: recovery gauges
                                // flow into snapshots, and any operator
                                // deadline override applies.
                                sess.set_fault_stats(fault_stats.clone());
                                if verify_deadline_ms > 0.0 {
                                    sess.ctl().set_verify_deadline_ms(verify_deadline_ms);
                                }
                                sess.ctl().set_parallel_draft(parallel_draft);
                                // Hand the session's live control surface
                                // to the adaptive controller.
                                if let Some(reg) = registry.as_ref() {
                                    relock(reg).insert(sess.session_id(), sess.ctl());
                                }
                            }
                            backend = Some(b);
                        }
                        // Tenant weight × SLO multiplier → the session's
                        // fair-share weight in the controller water-fill,
                        // refreshed per request (slots are reused across
                        // tenants).
                        if let Some(Backend::Dsi(sess)) = backend.as_ref() {
                            sess.ctl().set_weight(req.effective_weight());
                        }
                        // Membership changed (a session became active):
                        // kick the controller to re-water-fill now.
                        if let Some(sig) = tick_signal.as_ref() {
                            ctl_stats.record_membership_kick();
                            sig.kick();
                        }
                        let out = backend.as_mut().expect("backend built above").run(&cfg);
                        active.fetch_sub(1, Ordering::AcqRel);
                        if let Some(sig) = tick_signal.as_ref() {
                            ctl_stats.record_membership_kick();
                            sig.kick();
                        }

                        // Feed the estimators with the true outcome
                        // counts (§F.2 online variant). The global
                        // counter always learns; the per-session EWMA is
                        // fed here only on the static path — under the
                        // controller it learns mid-run from telemetry
                        // deltas instead, so nothing is double-counted.
                        {
                            let mut r = relock(&router);
                            match backend.as_ref() {
                                Some(Backend::Dsi(sess)) if !adaptive => r
                                    .observe_session_run(
                                        sess.session_id(),
                                        out.accepted_drafts,
                                        out.rejections,
                                    ),
                                _ => r.observe_run(out.accepted_drafts, out.rejections),
                            }
                        }

                        let resp = Response {
                            id: req.id,
                            text: tokenizer::decode(&out.tokens),
                            tokens: out.tokens,
                            ttft_ms: out.ttft_ms,
                            wall_ms: out.wall_ms,
                            queue_ms,
                            algo,
                            lookahead: plan.lookahead,
                            sp_degree: plan.sp_degree,
                            tenant: req.tenant,
                            weight: req.weight,
                            slo: req.slo,
                        };
                        {
                            let mut m = relock(&metrics);
                            m.note_complete_at(epoch.elapsed().as_secs_f64() * 1e3);
                            m.observe(&resp);
                        }
                        // Bump the wave barrier before handing the
                        // response off (run-to-completion admission waits
                        // on this count).
                        {
                            let (lock, cv) = &*completed;
                            *relock(lock) += 1;
                            cv.notify_all();
                        }
                        if resp_tx.send((idx, resp)).is_err() {
                            break;
                        }
                    }
                    // Worker exit: its session (if any) departs — drop
                    // the live-control registration and the router's
                    // estimator state for it.
                    if let Some(Backend::Dsi(sess)) = backend.as_ref() {
                        if let Some(reg) = registry.as_ref() {
                            relock(reg).remove(&sess.session_id());
                        }
                        relock(&router).retire_session(sess.session_id());
                    }
                });
            }
            drop(resp_tx);

            // Admission: open-loop pacing on this thread. Continuous mode
            // enqueues each request at its arrival instant — workers
            // refill freed slots immediately. Run-to-completion admits in
            // waves of `n_workers` and barriers on the completion counter
            // until the whole wave drains before admitting the next (the
            // gang baseline: freed slots idle out the wave tail).
            'admit: for (wave_no, wave) in order.chunks(n_workers).enumerate() {
                for &idx in wave {
                    let arrival = requests[idx].arrival_ms;
                    let now_ms = t0.elapsed().as_secs_f64() * 1e3;
                    if arrival > now_ms {
                        crate::coordinator::wait_engine::precise_wait(arrival - now_ms);
                    }
                    if job_tx.send(idx).is_err() {
                        break 'admit;
                    }
                }
                if admission == AdmissionMode::RunToCompletion {
                    let wave_end = (wave_no + 1) * n_workers;
                    let target = wave_end.min(order.len());
                    let (lock, cv) = &*completed;
                    let mut done = relock(lock);
                    while *done < target {
                        done = cv.wait(done).unwrap_or_else(PoisonError::into_inner);
                    }
                }
            }
            drop(job_tx); // closes the admission queue; workers drain and exit
        });

        // Workers joined: stop the control plane (its last applied plan
        // and gauges persist in ControllerStats for post-run snapshots).
        ctl_stop.store(true, Ordering::Release);
        if let Some(sig) = tick_signal.as_ref() {
            sig.kick(); // wake the controller out of its inter-tick sleep
        }
        if let Some(h) = ctl_thread {
            let _ = h.join();
        }

        // All workers joined: drain responses back into request order.
        let mut slots: Vec<Option<Response>> = (0..requests.len()).map(|_| None).collect();
        while let Ok((idx, resp)) = resp_rx.try_recv() {
            slots[idx] = Some(resp);
        }
        slots
            .into_iter()
            .map(|r| r.expect("a scheduler worker died mid-request"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LatencyProfile;
    use crate::coordinator::wait_engine::{Oracle, WaitEngine};
    use crate::workload::{PromptGen, PromptProfile};

    fn wait_factory(p: f64) -> (ServerFactory, WaitEngine) {
        let eng = WaitEngine {
            target: LatencyProfile::uniform(2.0),
            drafter: LatencyProfile::uniform(0.4),
            oracle: Oracle { vocab: 256, acceptance_rate: p, seed: 5 },
            max_context: 4096,
        };
        (eng.factory(), eng)
    }

    #[test]
    fn serves_closed_loop_and_records_metrics() {
        let (factory, _) = wait_factory(0.9);
        let router = Router::new(LatencyProfile::uniform(2.0), LatencyProfile::uniform(0.4), 4);
        let mut srv = Server::new(factory, router, AlgoKind::Dsi);
        let mut gen = PromptGen::new(1, 256);
        let reqs = gen.closed_loop(4, PromptProfile::Instruction, 12);
        let resps = srv.serve(&reqs);
        assert_eq!(resps.len(), 4);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, i as u64, "responses in request order");
            assert_eq!(r.tokens.len(), 12);
            assert!(r.wall_ms > 0.0);
        }
        let snap = srv.metrics_snapshot();
        assert_eq!(snap.requests, 4);
        assert_eq!(snap.tokens, 48);
        assert!(snap.tokens_per_s > 0.0);
        assert_eq!(snap.active_sessions, 0);
        // DSI serving runs through the shared pool: the dispatch-path
        // gauges must be live.
        assert!(snap.pool_tasks > 0, "pool task gauge not wired");
        assert!(snap.pool_queue_wait_us_mean >= 0.0);
        assert!(snap.pool_dispatch_us_mean >= 0.0);
        assert!(!srv.acceptance_estimate().is_nan());
    }

    #[test]
    fn dsi_server_beats_si_server_on_throughput() {
        // Latencies large enough that the expected DSI-vs-SI margin (~2x
        // at p=0.95) dwarfs scheduling noise from parallel test threads.
        let mut walls = Vec::new();
        for algo in [AlgoKind::Dsi, AlgoKind::Si] {
            let eng = WaitEngine {
                target: LatencyProfile::uniform(6.0),
                drafter: LatencyProfile::uniform(1.0),
                oracle: Oracle { vocab: 256, acceptance_rate: 0.95, seed: 5 },
                max_context: 4096,
            };
            let router =
                Router::new(LatencyProfile::uniform(6.0), LatencyProfile::uniform(1.0), 4);
            let mut srv = Server::new(eng.factory(), router, algo);
            let mut gen = PromptGen::new(1, 256);
            let reqs = gen.closed_loop(3, PromptProfile::Instruction, 24);
            let resps = srv.serve(&reqs);
            walls.push(resps.iter().map(|r| r.wall_ms).sum::<f64>());
        }
        assert!(walls[0] < walls[1], "DSI {} !< SI {}", walls[0], walls[1]);
    }

    #[test]
    fn open_loop_respects_arrivals() {
        let (factory, _) = wait_factory(0.9);
        let router = Router::new(LatencyProfile::uniform(1.0), LatencyProfile::uniform(0.3), 2);
        let mut srv = Server::new(factory, router, AlgoKind::NonSi);
        let mut gen = PromptGen::new(2, 256);
        let reqs = gen.open_loop(3, PromptProfile::Instruction, 4, 50.0);
        let t0 = Instant::now();
        let _ = srv.serve(&reqs);
        let elapsed_ms = t0.elapsed().as_secs_f64() * 1e3;
        assert!(elapsed_ms >= reqs.last().unwrap().arrival_ms);
    }

    #[test]
    fn pearl_serves_through_si_path_losslessly() {
        // The PEARL algo must actually speculate (SI path), not silently
        // run non-SI, and must stay lossless.
        let (factory, eng) = wait_factory(0.8);
        let router = Router::new(LatencyProfile::uniform(2.0), LatencyProfile::uniform(0.4), 4);
        let mut srv = Server::new(factory, router, AlgoKind::Pearl);
        let mut gen = PromptGen::new(3, 256);
        let reqs = gen.closed_loop(2, PromptProfile::Instruction, 10);
        let resps = srv.serve(&reqs);
        for (req, resp) in reqs.iter().zip(&resps) {
            assert_eq!(resp.algo, AlgoKind::Pearl);
            let cfg = crate::coordinator::OnlineConfig {
                prompt: req.prompt.clone(),
                n_tokens: req.max_new_tokens,
                lookahead: 1,
                sp_degree: 1,
                max_speculation_depth: 24,
            };
            let nonsi = crate::coordinator::run_nonsi(&eng.factory(), &cfg);
            assert_eq!(resp.tokens, nonsi.tokens, "PEARL-as-SI lost tokens");
        }
        // It used the drafter: the estimator saw accept/reject outcomes.
        assert!(!srv.acceptance_estimate().is_nan());
    }

    #[test]
    fn estimator_sees_true_rejection_counts() {
        // p=1.0: zero rejections; the estimator must not be fed a
        // fabricated rejection per run, so the estimate is exactly 1.
        let (factory, _) = wait_factory(1.0);
        let router = Router::new(LatencyProfile::uniform(2.0), LatencyProfile::uniform(0.4), 4);
        let mut srv = Server::new(factory, router, AlgoKind::Dsi);
        let mut gen = PromptGen::new(4, 256);
        let reqs = gen.closed_loop(2, PromptProfile::Instruction, 16);
        let _ = srv.serve(&reqs);
        let est = srv.acceptance_estimate();
        assert!(est > 0.95, "estimate {est} biased low by phantom rejections");
    }

    /// Run-to-completion barriers the wave: a short request stuck behind
    /// a long wave-mate dispatches only when the whole wave drains, while
    /// continuous admission refills the freed slot immediately. Outputs
    /// are identical either way — admission policy is not allowed to
    /// change tokens.
    #[test]
    fn rtc_barriers_waves_continuous_refills_slots() {
        let mk_reqs = || {
            let mut gen = PromptGen::new(11, 256);
            let mut reqs = gen.closed_loop(4, PromptProfile::Instruction, 5);
            reqs[0].max_new_tokens = 30; // the wave-1 straggler
            reqs
        };
        let serve = |mode: AdmissionMode| {
            let (factory, _) = wait_factory(0.9);
            let router =
                Router::new(LatencyProfile::uniform(3.0), LatencyProfile::uniform(0.4), 2);
            let mut srv = Server::new(factory, router, AlgoKind::NonSi)
                .with_max_sessions(2)
                .with_admission_mode(mode);
            srv.serve(&mk_reqs())
        };
        let cont = serve(AdmissionMode::Continuous);
        let rtc = serve(AdmissionMode::RunToCompletion);
        for (c, r) in cont.iter().zip(&rtc) {
            assert_eq!(c.tokens, r.tokens, "admission mode changed outputs");
        }
        // Request 2 heads wave 2: under RTC it waits out the 30-token
        // straggler (~90ms at 3ms/token); under continuous it takes the
        // slot the 5-token request freed (~15ms).
        assert!(
            rtc[2].queue_ms > cont[2].queue_ms + 30.0,
            "RTC queue {:.1}ms !> continuous queue {:.1}ms + margin",
            rtc[2].queue_ms,
            cont[2].queue_ms
        );
        assert!(rtc[2].queue_ms > 60.0, "wave barrier not observed");
    }

    /// Tenant / weight / SLO tags survive admission into the response.
    #[test]
    fn tags_survive_admission_into_responses() {
        use crate::workload::{SloClass, TenantSpec};
        let (factory, _) = wait_factory(0.9);
        let router = Router::new(LatencyProfile::uniform(1.0), LatencyProfile::uniform(0.3), 2);
        let mut srv = Server::new(factory, router, AlgoKind::NonSi);
        let mut gen = PromptGen::new(9, 256);
        let tenants = [
            TenantSpec { tenant: 7, weight: 2.0, slo: SloClass::Interactive },
            TenantSpec { tenant: 8, weight: 1.0, slo: SloClass::Batch },
        ];
        let reqs = gen.trace_tagged(
            4,
            PromptProfile::Instruction,
            4,
            crate::workload::ArrivalProcess::Poisson { rate_per_s: 1000.0 },
            &tenants,
        );
        let resps = srv.serve(&reqs);
        for (req, resp) in reqs.iter().zip(&resps) {
            assert_eq!((resp.tenant, resp.weight, resp.slo), (req.tenant, req.weight, req.slo));
        }
        assert_eq!(resps[0].tenant, 7);
        assert_eq!(resps[1].slo, SloClass::Batch);
    }

    #[test]
    fn concurrent_sessions_stay_lossless_and_ordered() {
        let (factory, eng) = wait_factory(0.85);
        let router = Router::new(LatencyProfile::uniform(2.0), LatencyProfile::uniform(0.4), 4);
        let mut srv = Server::new(factory, router, AlgoKind::Dsi)
            .with_max_sessions(3)
            .with_pool_size(4);
        let mut gen = PromptGen::new(7, 256);
        let reqs = gen.closed_loop(6, PromptProfile::Instruction, 10);
        let resps = srv.serve(&reqs);
        assert_eq!(resps.len(), 6);
        for (req, resp) in reqs.iter().zip(&resps) {
            assert_eq!(resp.id, req.id);
            let cfg = crate::coordinator::OnlineConfig {
                prompt: req.prompt.clone(),
                n_tokens: req.max_new_tokens,
                lookahead: 1,
                sp_degree: 1,
                max_speculation_depth: 24,
            };
            let nonsi = crate::coordinator::run_nonsi(&eng.factory(), &cfg);
            assert_eq!(resp.tokens, nonsi.tokens, "req {} lost tokens", req.id);
        }
        assert_eq!(srv.active_sessions(), 0);
    }
}
