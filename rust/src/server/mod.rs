//! The serving front: request handling on top of the DSI coordinator.
//!
//! A downstream user deploys DSI behind this layer: requests arrive (open
//! or closed loop), the [`router`] picks the operating point (lookahead /
//! SP split via Equation 1, from calibrated latencies and the online
//! acceptance-rate estimate), the generation loop runs the selected
//! algorithm, and [`metrics`] aggregates TTFT/TPOT/throughput.

pub mod metrics;
pub mod router;

use crate::config::AlgoKind;
use crate::coordinator::{
    run_nonsi_with, run_si_with, DsiPipeline, LmServer, OnlineConfig, ServerFactory,
    ServerRole,
};
use crate::runtime::tokenizer;
use crate::workload::Request;
use metrics::Metrics;
use router::Router;
use std::time::Instant;

/// A completed request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub text: String,
    /// Wall ms from dispatch to first output token.
    pub ttft_ms: f64,
    /// Wall ms for the whole generation.
    pub wall_ms: f64,
    /// Queueing delay before dispatch, ms.
    pub queue_ms: f64,
    pub algo: AlgoKind,
    pub lookahead: usize,
}

/// Serving engine: owns the router and metrics; executes requests
/// sequentially (one generation at a time — the single-node regime where
/// DSI spends the node's GPUs on speculation parallelism rather than
/// request parallelism).
pub struct Server {
    factory: ServerFactory,
    pub router: Router,
    pub metrics: Metrics,
    algo: AlgoKind,
    max_speculation_depth: usize,
    /// Persistent DSI pipeline (threads + loaded models live across
    /// requests); lazily constructed on the first DSI request.
    dsi: Option<DsiPipeline>,
    /// Persistent single servers for the sequential baselines.
    target_srv: Option<Box<dyn LmServer>>,
    drafter_srv: Option<Box<dyn LmServer>>,
}

impl Server {
    pub fn new(factory: ServerFactory, router: Router, algo: AlgoKind) -> Self {
        Self {
            factory,
            router,
            metrics: Metrics::new(),
            algo,
            max_speculation_depth: 24,
            dsi: None,
            target_srv: None,
            drafter_srv: None,
        }
    }

    pub fn with_max_depth(mut self, depth: usize) -> Self {
        self.max_speculation_depth = depth;
        self
    }

    /// Serve a full workload; honors arrival times (open loop) by waiting.
    pub fn serve(&mut self, requests: &[Request]) -> Vec<Response> {
        let epoch = Instant::now();
        let mut responses = Vec::with_capacity(requests.len());
        for req in requests {
            // Open-loop pacing.
            let now_ms = epoch.elapsed().as_secs_f64() * 1e3;
            if req.arrival_ms > now_ms {
                crate::coordinator::wait_engine::precise_wait(req.arrival_ms - now_ms);
            }
            let dispatched_ms = epoch.elapsed().as_secs_f64() * 1e3;
            let queue_ms = (dispatched_ms - req.arrival_ms).max(0.0);

            let resp = self.execute(req, queue_ms);
            self.metrics.observe(&resp);
            responses.push(resp);
        }
        responses
    }

    fn execute(&mut self, req: &Request, queue_ms: f64) -> Response {
        let plan = self.router.plan(self.algo);
        let cfg = OnlineConfig {
            prompt: req.prompt.clone(),
            n_tokens: req.max_new_tokens,
            lookahead: plan.lookahead,
            sp_degree: plan.sp_degree,
            max_speculation_depth: self.max_speculation_depth,
        };
        let out = match self.algo {
            AlgoKind::Dsi => {
                let factory = &self.factory;
                let sp = plan.sp_degree;
                self.dsi
                    .get_or_insert_with(|| DsiPipeline::new(factory, sp))
                    .generate(&cfg)
            }
            AlgoKind::Si => {
                let factory = &self.factory;
                let target = self
                    .target_srv
                    .get_or_insert_with(|| factory(ServerRole::Target, 0));
                let drafter = self
                    .drafter_srv
                    .get_or_insert_with(|| factory(ServerRole::Drafter, 0));
                run_si_with(target.as_mut(), drafter.as_mut(), &cfg)
            }
            AlgoKind::NonSi | AlgoKind::Pearl => {
                let factory = &self.factory;
                let target = self
                    .target_srv
                    .get_or_insert_with(|| factory(ServerRole::Target, 0));
                run_nonsi_with(target.as_mut(), &cfg)
            }
        };
        // Feed the acceptance estimator (§F.2 online variant).
        self.router
            .observe_run(out.accepted_drafts, out.rejections.max(1));

        Response {
            id: req.id,
            text: tokenizer::decode(&out.tokens),
            tokens: out.tokens,
            ttft_ms: out.ttft_ms,
            wall_ms: out.wall_ms,
            queue_ms,
            algo: self.algo,
            lookahead: plan.lookahead,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LatencyProfile;
    use crate::coordinator::wait_engine::{Oracle, WaitEngine};
    use crate::workload::{PromptGen, PromptProfile};

    fn wait_factory(p: f64) -> (ServerFactory, WaitEngine) {
        let eng = WaitEngine {
            target: LatencyProfile::uniform(2.0),
            drafter: LatencyProfile::uniform(0.4),
            oracle: Oracle { vocab: 256, acceptance_rate: p, seed: 5 },
            max_context: 4096,
        };
        (eng.factory(), eng)
    }

    #[test]
    fn serves_closed_loop_and_records_metrics() {
        let (factory, _) = wait_factory(0.9);
        let router = Router::new(LatencyProfile::uniform(2.0), LatencyProfile::uniform(0.4), 4);
        let mut srv = Server::new(factory, router, AlgoKind::Dsi);
        let mut gen = PromptGen::new(1, 256);
        let reqs = gen.closed_loop(4, PromptProfile::Instruction, 12);
        let resps = srv.serve(&reqs);
        assert_eq!(resps.len(), 4);
        for r in &resps {
            assert_eq!(r.tokens.len(), 12);
            assert!(r.wall_ms > 0.0);
        }
        let snap = srv.metrics.snapshot();
        assert_eq!(snap.requests, 4);
        assert_eq!(snap.tokens, 48);
        assert!(snap.tokens_per_s > 0.0);
    }

    #[test]
    fn dsi_server_beats_si_server_on_throughput() {
        // Latencies large enough that the expected DSI-vs-SI margin (~2x
        // at p=0.95) dwarfs scheduling noise from parallel test threads.
        let mut walls = Vec::new();
        for algo in [AlgoKind::Dsi, AlgoKind::Si] {
            let eng = WaitEngine {
                target: LatencyProfile::uniform(6.0),
                drafter: LatencyProfile::uniform(1.0),
                oracle: Oracle { vocab: 256, acceptance_rate: 0.95, seed: 5 },
                max_context: 4096,
            };
            let router =
                Router::new(LatencyProfile::uniform(6.0), LatencyProfile::uniform(1.0), 4);
            let mut srv = Server::new(eng.factory(), router, algo);
            let mut gen = PromptGen::new(1, 256);
            let reqs = gen.closed_loop(3, PromptProfile::Instruction, 24);
            let resps = srv.serve(&reqs);
            walls.push(resps.iter().map(|r| r.wall_ms).sum::<f64>());
        }
        assert!(walls[0] < walls[1], "DSI {} !< SI {}", walls[0], walls[1]);
    }

    #[test]
    fn open_loop_respects_arrivals() {
        let (factory, _) = wait_factory(0.9);
        let router = Router::new(LatencyProfile::uniform(1.0), LatencyProfile::uniform(0.3), 2);
        let mut srv = Server::new(factory, router, AlgoKind::NonSi);
        let mut gen = PromptGen::new(2, 256);
        let reqs = gen.open_loop(3, PromptProfile::Instruction, 4, 50.0);
        let t0 = Instant::now();
        let _ = srv.serve(&reqs);
        let elapsed_ms = t0.elapsed().as_secs_f64() * 1e3;
        assert!(elapsed_ms >= reqs.last().unwrap().arrival_ms);
    }
}
