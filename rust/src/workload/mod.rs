//! Workload generation: synthetic prompt corpora and arrival processes
//! for the serving-front experiments.
//!
//! The paper's datasets (CNN-DM, Alpaca, MBPP, HumanEval) enter its
//! evaluation only through measured latencies and acceptance rates (§F);
//! for the end-to-end serving runs we generate deterministic byte-level
//! prompts with dataset-like length profiles.

use crate::util::Rng64;

/// Length profile of a synthetic "dataset".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PromptProfile {
    /// Short instructions (Alpaca-like): 8-32 tokens.
    Instruction,
    /// Long documents (CNN-DM-like): 48-96 tokens (scaled to our 128 ctx).
    Summarization,
    /// Code stubs (MBPP/HumanEval-like): 16-64 tokens.
    Code,
}

impl PromptProfile {
    pub fn len_range(&self) -> (usize, usize) {
        match self {
            PromptProfile::Instruction => (8, 32),
            PromptProfile::Summarization => (48, 96),
            PromptProfile::Code => (16, 64),
        }
    }

    pub const ALL: [PromptProfile; 3] = [
        PromptProfile::Instruction,
        PromptProfile::Summarization,
        PromptProfile::Code,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            PromptProfile::Instruction => "instruction",
            PromptProfile::Summarization => "summarization",
            PromptProfile::Code => "code",
        }
    }
}

/// SLO class of a request — coarse latency expectation that scales the
/// tenant's weight in the controller's min-max water-fill. Interactive
/// traffic outbids batch traffic for SP lanes; standard is the neutral
/// default (multiplier 1.0, so untagged workloads are unchanged).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloClass {
    /// Chat-like: user is waiting on every token.
    Interactive,
    /// Default request class.
    Standard,
    /// Offline/bulk: throughput matters, latency does not.
    Batch,
}

impl SloClass {
    /// Multiplier applied to the tenant weight before water-filling.
    pub fn weight_multiplier(&self) -> f64 {
        match self {
            SloClass::Interactive => 2.0,
            SloClass::Standard => 1.0,
            SloClass::Batch => 0.5,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Option<SloClass> {
        match s {
            "interactive" => Some(SloClass::Interactive),
            "standard" => Some(SloClass::Standard),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }
}

/// Per-tenant tagging spec for [`PromptGen::trace_tagged`].
#[derive(Debug, Clone, Copy)]
pub struct TenantSpec {
    pub tenant: u32,
    /// Fair-share weight (> 0); scales the session's claim on SP lanes.
    pub weight: f64,
    pub slo: SloClass,
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Arrival offset from workload start, ms (0 for closed-loop runs).
    pub arrival_ms: f64,
    /// Tenant identity; flows through serving into the `Response`.
    pub tenant: u32,
    /// Fair-share weight for the water-fill (default 1.0).
    pub weight: f64,
    pub slo: SloClass,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize, arrival_ms: f64) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            arrival_ms,
            tenant: 0,
            weight: 1.0,
            slo: SloClass::Standard,
        }
    }

    /// Effective scheduling weight: tenant weight scaled by SLO class.
    pub fn effective_weight(&self) -> f64 {
        (self.weight * self.slo.weight_multiplier()).max(f64::MIN_POSITIVE)
    }
}

/// Arrival process for open-loop traces. All variants are simulated
/// exactly (memoryless state switching, thinning) so the configured mean
/// rate is reproduced, not approximated.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson at `rate_per_s`.
    Poisson { rate_per_s: f64 },
    /// Two-state Markov-modulated Poisson process: exponential dwell in a
    /// calm state at `calm_rate_per_s`, exponential dwell in a burst
    /// state at `burst_rate_per_s`. The classic bursty-traffic model —
    /// bursts of arrivals separated by quiet stretches.
    Bursty {
        calm_rate_per_s: f64,
        burst_rate_per_s: f64,
        calm_dwell_ms: f64,
        burst_dwell_ms: f64,
    },
    /// Sinusoidally-modulated Poisson (diurnal pattern scaled down):
    /// rate(t) = mean · (1 + amplitude · sin(2πt/period)). Simulated by
    /// thinning against the peak rate. `amplitude` in [0, 1).
    Diurnal {
        mean_rate_per_s: f64,
        period_ms: f64,
        amplitude: f64,
    },
}

impl ArrivalProcess {
    /// Long-run mean arrival rate of the process, requests per second.
    pub fn mean_rate_per_s(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_per_s } => rate_per_s,
            ArrivalProcess::Bursty {
                calm_rate_per_s,
                burst_rate_per_s,
                calm_dwell_ms,
                burst_dwell_ms,
            } => {
                (calm_rate_per_s * calm_dwell_ms + burst_rate_per_s * burst_dwell_ms)
                    / (calm_dwell_ms + burst_dwell_ms)
            }
            ArrivalProcess::Diurnal { mean_rate_per_s, .. } => mean_rate_per_s,
        }
    }

    /// A bursty preset with a 6:1 burst-to-calm rate ratio and 3:1
    /// calm-to-burst dwell ratio, scaled so the long-run mean rate is
    /// `mean_rate_per_s`.
    pub fn bursty_preset(mean_rate_per_s: f64) -> ArrivalProcess {
        // mean = (0.5r·600 + 3r·200) / 800 = 1.125r  →  r = mean/1.125
        let r = mean_rate_per_s / 1.125;
        ArrivalProcess::Bursty {
            calm_rate_per_s: 0.5 * r,
            burst_rate_per_s: 3.0 * r,
            calm_dwell_ms: 600.0,
            burst_dwell_ms: 200.0,
        }
    }
}

/// Deterministic prompt generator.
pub struct PromptGen {
    rng: Rng64,
    vocab: u32,
}

impl PromptGen {
    pub fn new(seed: u64, vocab: u32) -> Self {
        Self { rng: Rng64::seed_from_u64(seed), vocab }
    }

    /// One prompt from a profile. Byte tokens are drawn from printable
    /// ASCII so decoded text is readable in logs.
    pub fn prompt(&mut self, profile: PromptProfile) -> Vec<u32> {
        let (lo, hi) = profile.len_range();
        let len = lo + self.rng.gen_range(hi - lo + 1);
        (0..len)
            .map(|_| {
                let b = 32 + self.rng.gen_range(95) as u32; // ' '..'~'
                b.min(self.vocab - 1)
            })
            .collect()
    }

    /// A closed-loop batch of requests (all arrive at t=0).
    pub fn closed_loop(
        &mut self,
        n: usize,
        profile: PromptProfile,
        max_new_tokens: usize,
    ) -> Vec<Request> {
        (0..n)
            .map(|i| Request::new(i as u64, self.prompt(profile), max_new_tokens, 0.0))
            .collect()
    }

    /// Burst arrivals for concurrent admission: `n` requests arriving in
    /// groups of `burst` at the same instant, bursts spaced `gap_ms`
    /// apart. The adversarial pattern for a multi-session scheduler —
    /// every burst demands `burst` generations at once, so the SP budget
    /// must be split rather than time-shared.
    pub fn bursts(
        &mut self,
        n: usize,
        profile: PromptProfile,
        max_new_tokens: usize,
        burst: usize,
        gap_ms: f64,
    ) -> Vec<Request> {
        let burst = burst.max(1);
        (0..n)
            .map(|i| {
                Request::new(
                    i as u64,
                    self.prompt(profile),
                    max_new_tokens,
                    (i / burst) as f64 * gap_ms,
                )
            })
            .collect()
    }

    /// An open-loop Poisson arrival trace at `rate_per_s`. Equivalent to
    /// [`PromptGen::trace`] with [`ArrivalProcess::Poisson`] (identical
    /// draw order, so existing seeds reproduce byte-identical traces).
    pub fn open_loop(
        &mut self,
        n: usize,
        profile: PromptProfile,
        max_new_tokens: usize,
        rate_per_s: f64,
    ) -> Vec<Request> {
        self.trace(n, profile, max_new_tokens, ArrivalProcess::Poisson { rate_per_s })
    }

    /// Draw the next inter-arrival and advance the process state.
    /// `state`: (in_burst, state_end_ms) for the MMPP variant.
    fn next_arrival(
        &mut self,
        t: f64,
        process: ArrivalProcess,
        state: &mut (bool, f64),
    ) -> f64 {
        match process {
            ArrivalProcess::Poisson { rate_per_s } => {
                t + self.rng.gen_exp(1000.0 / rate_per_s)
            }
            ArrivalProcess::Bursty {
                calm_rate_per_s,
                burst_rate_per_s,
                calm_dwell_ms,
                burst_dwell_ms,
            } => {
                // Exact MMPP simulation: the exponential clock is
                // memoryless, so a candidate arrival that overshoots the
                // current dwell is discarded and redrawn at the new
                // state's rate from the switch instant.
                let mut t = t;
                loop {
                    let rate = if state.0 { burst_rate_per_s } else { calm_rate_per_s };
                    debug_assert!(rate > 0.0);
                    let cand = t + self.rng.gen_exp(1000.0 / rate);
                    if cand <= state.1 {
                        return cand;
                    }
                    t = state.1;
                    state.0 = !state.0;
                    let next_dwell = if state.0 { burst_dwell_ms } else { calm_dwell_ms };
                    state.1 = t + self.rng.gen_exp(next_dwell);
                }
            }
            ArrivalProcess::Diurnal { mean_rate_per_s, period_ms, amplitude } => {
                // Thinning (Lewis-Shedler): candidates at the peak rate,
                // accepted with probability rate(t)/peak — exact for any
                // bounded rate function.
                assert!((0.0..1.0).contains(&amplitude), "amplitude {amplitude}");
                let peak = mean_rate_per_s * (1.0 + amplitude);
                let mut t = t;
                loop {
                    t += self.rng.gen_exp(1000.0 / peak);
                    let phase = 2.0 * std::f64::consts::PI * t / period_ms;
                    let rate = mean_rate_per_s * (1.0 + amplitude * phase.sin());
                    if self.rng.gen_f64() < rate / peak {
                        return t;
                    }
                }
            }
        }
    }

    /// An open-loop arrival trace under any [`ArrivalProcess`], untagged
    /// (tenant 0, weight 1, standard SLO).
    pub fn trace(
        &mut self,
        n: usize,
        profile: PromptProfile,
        max_new_tokens: usize,
        process: ArrivalProcess,
    ) -> Vec<Request> {
        self.trace_tagged(n, profile, max_new_tokens, process, &[])
    }

    /// An open-loop trace with per-tenant weight/SLO tags assigned
    /// round-robin over `tenants` (deterministic, so every tenant sees
    /// the same share of arrivals). Empty `tenants` means untagged.
    pub fn trace_tagged(
        &mut self,
        n: usize,
        profile: PromptProfile,
        max_new_tokens: usize,
        process: ArrivalProcess,
        tenants: &[TenantSpec],
    ) -> Vec<Request> {
        let mut t = 0.0;
        // MMPP starts in the calm state with a fresh dwell.
        let mut state = (false, 0.0);
        if let ArrivalProcess::Bursty { calm_dwell_ms, .. } = process {
            state.1 = self.rng.gen_exp(calm_dwell_ms);
        }
        (0..n)
            .map(|i| {
                t = self.next_arrival(t, process, &mut state);
                let mut req = Request::new(i as u64, self.prompt(profile), max_new_tokens, t);
                if !tenants.is_empty() {
                    let spec = tenants[i % tenants.len()];
                    req.tenant = spec.tenant;
                    req.weight = spec.weight;
                    req.slo = spec.slo;
                }
                req
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_lengths_in_profile_range() {
        let mut g = PromptGen::new(1, 256);
        for profile in PromptProfile::ALL {
            let (lo, hi) = profile.len_range();
            for _ in 0..100 {
                let p = g.prompt(profile);
                assert!(p.len() >= lo && p.len() <= hi);
                assert!(p.iter().all(|&t| t < 256));
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = PromptGen::new(7, 256).prompt(PromptProfile::Code);
        let b = PromptGen::new(7, 256).prompt(PromptProfile::Code);
        assert_eq!(a, b);
    }

    #[test]
    fn bursts_arrive_in_groups() {
        let mut g = PromptGen::new(5, 256);
        let reqs = g.bursts(7, PromptProfile::Instruction, 8, 3, 25.0);
        assert_eq!(reqs.len(), 7);
        let arrivals: Vec<f64> = reqs.iter().map(|r| r.arrival_ms).collect();
        assert_eq!(arrivals[0..3], [0.0, 0.0, 0.0]);
        assert_eq!(arrivals[3..6], [25.0, 25.0, 25.0]);
        assert_eq!(arrivals[6], 50.0);
        // ids stay in order for response reordering
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn open_loop_arrivals_increase() {
        let mut g = PromptGen::new(3, 256);
        let reqs = g.open_loop(50, PromptProfile::Instruction, 16, 100.0);
        for w in reqs.windows(2) {
            assert!(w[0].arrival_ms < w[1].arrival_ms);
        }
        // mean inter-arrival ~ 10ms at 100 req/s
        let mean = reqs.last().unwrap().arrival_ms / 50.0;
        assert!((5.0..20.0).contains(&mean), "mean gap {mean}");
    }

    /// Empirical rate of a trace, requests per second.
    fn empirical_rate(reqs: &[Request]) -> f64 {
        reqs.len() as f64 / (reqs.last().unwrap().arrival_ms / 1000.0)
    }

    #[test]
    fn open_loop_poisson_rate_is_accurate() {
        let mut g = PromptGen::new(11, 256);
        let reqs = g.open_loop(20_000, PromptProfile::Instruction, 8, 250.0);
        let rate = empirical_rate(&reqs);
        assert!((rate / 250.0 - 1.0).abs() < 0.05, "rate {rate}");
    }

    #[test]
    fn open_loop_is_deterministic_across_seeds() {
        let mk = |seed| {
            PromptGen::new(seed, 256).open_loop(64, PromptProfile::Code, 8, 80.0)
        };
        let (a, b) = (mk(9), mk(9));
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.prompt, y.prompt);
        }
        // A different seed yields a different trace.
        let c = mk(10);
        assert!(a.iter().zip(c.iter()).any(|(x, y)| x.arrival_ms != y.arrival_ms));
    }

    #[test]
    fn open_loop_matches_poisson_trace() {
        // open_loop is a thin wrapper over trace(Poisson): same seed must
        // reproduce byte-identical arrivals AND prompts.
        let a = PromptGen::new(21, 256).open_loop(32, PromptProfile::Code, 8, 120.0);
        let b = PromptGen::new(21, 256).trace(
            32,
            PromptProfile::Code,
            8,
            ArrivalProcess::Poisson { rate_per_s: 120.0 },
        );
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.prompt, y.prompt);
        }
    }

    #[test]
    fn bursty_trace_reproduces_mean_rate() {
        let p = ArrivalProcess::Bursty {
            calm_rate_per_s: 50.0,
            burst_rate_per_s: 500.0,
            calm_dwell_ms: 2000.0,
            burst_dwell_ms: 500.0,
        };
        // mean = (50·2000 + 500·500) / 2500 = 140/s
        assert!((p.mean_rate_per_s() - 140.0).abs() < 1e-9);
        let mut g = PromptGen::new(13, 256);
        let reqs = g.trace(30_000, PromptProfile::Instruction, 8, p);
        let rate = empirical_rate(&reqs);
        assert!((rate / 140.0 - 1.0).abs() < 0.07, "rate {rate}");
        for w in reqs.windows(2) {
            assert!(w[0].arrival_ms < w[1].arrival_ms);
        }
    }

    #[test]
    fn bursty_preset_hits_requested_mean() {
        let p = ArrivalProcess::bursty_preset(40.0);
        assert!((p.mean_rate_per_s() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn diurnal_trace_reproduces_mean_rate() {
        let p = ArrivalProcess::Diurnal {
            mean_rate_per_s: 100.0,
            period_ms: 1000.0,
            amplitude: 0.8,
        };
        assert!((p.mean_rate_per_s() - 100.0).abs() < 1e-9);
        let mut g = PromptGen::new(17, 256);
        let reqs = g.trace(20_000, PromptProfile::Instruction, 8, p);
        // ~200 full periods, so the sinusoid integrates out.
        let rate = empirical_rate(&reqs);
        assert!((rate / 100.0 - 1.0).abs() < 0.05, "rate {rate}");
    }

    #[test]
    fn diurnal_rate_actually_varies_within_period() {
        // Split arrivals by sine phase: the peak half-period must see
        // substantially more traffic than the trough half-period.
        let period = 1000.0;
        let mut g = PromptGen::new(19, 256);
        let reqs = g.trace(
            20_000,
            PromptProfile::Instruction,
            8,
            ArrivalProcess::Diurnal { mean_rate_per_s: 100.0, period_ms: period, amplitude: 0.8 },
        );
        let (mut peak, mut trough) = (0usize, 0usize);
        for r in &reqs {
            let phase = (r.arrival_ms / period).fract();
            if phase < 0.5 {
                peak += 1; // sin > 0 half
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > 1.5 * trough as f64,
            "peak {peak} trough {trough}"
        );
    }

    #[test]
    fn tagged_trace_assigns_tenants_round_robin() {
        let tenants = [
            TenantSpec { tenant: 1, weight: 2.0, slo: SloClass::Interactive },
            TenantSpec { tenant: 2, weight: 1.0, slo: SloClass::Batch },
        ];
        let mut g = PromptGen::new(23, 256);
        let reqs = g.trace_tagged(
            10,
            PromptProfile::Instruction,
            8,
            ArrivalProcess::Poisson { rate_per_s: 50.0 },
            &tenants,
        );
        for (i, r) in reqs.iter().enumerate() {
            let spec = tenants[i % 2];
            assert_eq!(r.tenant, spec.tenant);
            assert_eq!(r.weight, spec.weight);
            assert_eq!(r.slo, spec.slo);
        }
        // Effective weight folds the SLO multiplier in.
        assert_eq!(reqs[0].effective_weight(), 4.0); // 2.0 × interactive 2.0
        assert_eq!(reqs[1].effective_weight(), 0.5); // 1.0 × batch 0.5
    }

    #[test]
    fn untagged_requests_default_to_neutral_tags() {
        let mut g = PromptGen::new(29, 256);
        let reqs = g.closed_loop(3, PromptProfile::Code, 8);
        for r in &reqs {
            assert_eq!(r.tenant, 0);
            assert_eq!(r.weight, 1.0);
            assert_eq!(r.slo, SloClass::Standard);
            assert_eq!(r.effective_weight(), 1.0);
        }
    }
}
