//! Workload generation: synthetic prompt corpora and arrival processes
//! for the serving-front experiments.
//!
//! The paper's datasets (CNN-DM, Alpaca, MBPP, HumanEval) enter its
//! evaluation only through measured latencies and acceptance rates (§F);
//! for the end-to-end serving runs we generate deterministic byte-level
//! prompts with dataset-like length profiles.

use crate::util::Rng64;

/// Length profile of a synthetic "dataset".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PromptProfile {
    /// Short instructions (Alpaca-like): 8-32 tokens.
    Instruction,
    /// Long documents (CNN-DM-like): 48-96 tokens (scaled to our 128 ctx).
    Summarization,
    /// Code stubs (MBPP/HumanEval-like): 16-64 tokens.
    Code,
}

impl PromptProfile {
    pub fn len_range(&self) -> (usize, usize) {
        match self {
            PromptProfile::Instruction => (8, 32),
            PromptProfile::Summarization => (48, 96),
            PromptProfile::Code => (16, 64),
        }
    }

    pub const ALL: [PromptProfile; 3] = [
        PromptProfile::Instruction,
        PromptProfile::Summarization,
        PromptProfile::Code,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            PromptProfile::Instruction => "instruction",
            PromptProfile::Summarization => "summarization",
            PromptProfile::Code => "code",
        }
    }
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Arrival offset from workload start, ms (0 for closed-loop runs).
    pub arrival_ms: f64,
}

/// Deterministic prompt generator.
pub struct PromptGen {
    rng: Rng64,
    vocab: u32,
}

impl PromptGen {
    pub fn new(seed: u64, vocab: u32) -> Self {
        Self { rng: Rng64::seed_from_u64(seed), vocab }
    }

    /// One prompt from a profile. Byte tokens are drawn from printable
    /// ASCII so decoded text is readable in logs.
    pub fn prompt(&mut self, profile: PromptProfile) -> Vec<u32> {
        let (lo, hi) = profile.len_range();
        let len = lo + self.rng.gen_range(hi - lo + 1);
        (0..len)
            .map(|_| {
                let b = 32 + self.rng.gen_range(95) as u32; // ' '..'~'
                b.min(self.vocab - 1)
            })
            .collect()
    }

    /// A closed-loop batch of requests (all arrive at t=0).
    pub fn closed_loop(
        &mut self,
        n: usize,
        profile: PromptProfile,
        max_new_tokens: usize,
    ) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                id: i as u64,
                prompt: self.prompt(profile),
                max_new_tokens,
                arrival_ms: 0.0,
            })
            .collect()
    }

    /// Burst arrivals for concurrent admission: `n` requests arriving in
    /// groups of `burst` at the same instant, bursts spaced `gap_ms`
    /// apart. The adversarial pattern for a multi-session scheduler —
    /// every burst demands `burst` generations at once, so the SP budget
    /// must be split rather than time-shared.
    pub fn bursts(
        &mut self,
        n: usize,
        profile: PromptProfile,
        max_new_tokens: usize,
        burst: usize,
        gap_ms: f64,
    ) -> Vec<Request> {
        let burst = burst.max(1);
        (0..n)
            .map(|i| Request {
                id: i as u64,
                prompt: self.prompt(profile),
                max_new_tokens,
                arrival_ms: (i / burst) as f64 * gap_ms,
            })
            .collect()
    }

    /// An open-loop Poisson arrival trace at `rate_per_s`.
    pub fn open_loop(
        &mut self,
        n: usize,
        profile: PromptProfile,
        max_new_tokens: usize,
        rate_per_s: f64,
    ) -> Vec<Request> {
        let mut t = 0.0;
        (0..n)
            .map(|i| {
                t += self.rng.gen_exp(1000.0 / rate_per_s);
                Request {
                    id: i as u64,
                    prompt: self.prompt(profile),
                    max_new_tokens,
                    arrival_ms: t,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_lengths_in_profile_range() {
        let mut g = PromptGen::new(1, 256);
        for profile in PromptProfile::ALL {
            let (lo, hi) = profile.len_range();
            for _ in 0..100 {
                let p = g.prompt(profile);
                assert!(p.len() >= lo && p.len() <= hi);
                assert!(p.iter().all(|&t| t < 256));
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = PromptGen::new(7, 256).prompt(PromptProfile::Code);
        let b = PromptGen::new(7, 256).prompt(PromptProfile::Code);
        assert_eq!(a, b);
    }

    #[test]
    fn bursts_arrive_in_groups() {
        let mut g = PromptGen::new(5, 256);
        let reqs = g.bursts(7, PromptProfile::Instruction, 8, 3, 25.0);
        assert_eq!(reqs.len(), 7);
        let arrivals: Vec<f64> = reqs.iter().map(|r| r.arrival_ms).collect();
        assert_eq!(arrivals[0..3], [0.0, 0.0, 0.0]);
        assert_eq!(arrivals[3..6], [25.0, 25.0, 25.0]);
        assert_eq!(arrivals[6], 50.0);
        // ids stay in order for response reordering
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn open_loop_arrivals_increase() {
        let mut g = PromptGen::new(3, 256);
        let reqs = g.open_loop(50, PromptProfile::Instruction, 16, 100.0);
        for w in reqs.windows(2) {
            assert!(w[0].arrival_ms < w[1].arrival_ms);
        }
        // mean inter-arrival ~ 10ms at 100 req/s
        let mean = reqs.last().unwrap().arrival_ms / 50.0;
        assert!((5.0..20.0).contains(&mean), "mean gap {mean}");
    }
}
